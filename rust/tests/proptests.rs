//! Property-based tests over randomized configurations, traces and
//! allocator workloads (deterministic xoshiro PRNG — proptest is not
//! available offline; shrinking is traded for seeds printed on failure).

use mmpredict::config::{OptimizerKind, Precision, Stage, TrainConfig, ZeroStage};
use mmpredict::model::layer::AttnImpl;
use mmpredict::model::lora::LoraConfig;
use mmpredict::simulator::allocator::CachingAllocator;
use mmpredict::util::Prng;
use mmpredict::{parser, predictor, simulator};

/// Draw a random *valid* training configuration.
fn arb_config(r: &mut Prng) -> TrainConfig {
    let stage = *r.pick(&[Stage::Pretrain, Stage::Finetune, Stage::LoraFinetune, Stage::Full]);
    TrainConfig {
        model: r.pick(&["llava-tiny", "llama-tiny"]).to_string(),
        stage,
        mbs: r.range(1, 16) as u64,
        seq_len: *r.pick(&[32u64, 64, 128, 256, 512]),
        images_per_sample: 1,
        clips_per_sample: 1,
        dp: *r.pick(&[1u64, 2, 3, 4, 8]),
        zero: *r.pick(&[ZeroStage::Zero0, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3]),
        optimizer: *r.pick(&[OptimizerKind::AdamW, OptimizerKind::SgdMomentum, OptimizerKind::Sgd]),
        precision: *r.pick(&[Precision::Bf16Mixed, Precision::Fp16Mixed, Precision::Fp32]),
        attn: *r.pick(&[AttnImpl::Flash, AttnImpl::Eager]),
        grad_checkpoint: r.chance(0.5),
        lora: (stage == Stage::LoraFinetune)
            .then(|| LoraConfig { rank: *r.pick(&[2u64, 8, 32]), ..Default::default() }),
        bucket_elems: *r.pick(&[1_000_000u64, 50_000_000, 500_000_000]),
        overheads: Default::default(),
    }
}

#[test]
fn prediction_invariants_hold_for_random_configs() {
    let mut r = Prng::new(0xC0FFEE);
    for case in 0..150 {
        let cfg = arb_config(&mut r);
        let p = predictor::predict(&cfg).unwrap_or_else(|e| panic!("case {case}: {e:#} {cfg:?}"));
        let check = |v: f32, name: &str| {
            assert!(v.is_finite() && v >= 0.0, "case {case}: {name}={v} {cfg:?}");
        };
        check(p.peak_mib, "peak");
        check(p.param_mib, "param");
        check(p.grad_mib, "grad");
        check(p.opt_mib, "opt");
        check(p.act_mib, "act");
        // Eq. 1 structure
        let sum = p.param_mib + p.grad_mib + p.opt_mib;
        assert!(
            (p.persistent_mib - sum).abs() <= sum.max(1.0) * 1e-4,
            "case {case}: persistent decomposition"
        );
        assert!(p.peak_mib >= p.persistent_mib, "case {case}");
        assert!(p.transient_mib >= p.fwd_peak_mib - 0.01, "case {case}");
    }
}

#[test]
fn predictor_vs_simulator_bounded_everywhere() {
    let mut r = Prng::new(42);
    for case in 0..60 {
        let cfg = arb_config(&mut r);
        let p = predictor::predict(&cfg).unwrap().peak_mib as f64;
        let m = simulator::simulate(&cfg).unwrap().peak_mib;
        let ape = (p - m).abs() / m;
        assert!(
            ape < 0.5,
            "case {case}: APE {ape:.3} (pred {p:.0} vs meas {m:.0}) for {cfg:?}"
        );
    }
}

#[test]
fn peak_monotone_in_mbs() {
    let mut r = Prng::new(7);
    for case in 0..40 {
        let mut cfg = arb_config(&mut r);
        cfg.mbs = r.range(1, 8) as u64;
        let p1 = predictor::predict(&cfg).unwrap().peak_mib;
        let mut cfg2 = cfg.clone();
        cfg2.mbs = cfg.mbs * 2;
        let p2 = predictor::predict(&cfg2).unwrap().peak_mib;
        assert!(p2 >= p1 - 0.5, "case {case}: mbs x2 shrank peak: {p1} -> {p2} {cfg:?}");
    }
}

#[test]
fn sharded_factors_never_grow_with_dp() {
    let mut r = Prng::new(99);
    for case in 0..40 {
        let mut cfg = arb_config(&mut r);
        cfg.dp = 2;
        let lo = predictor::predict(&cfg).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.dp = 8;
        let hi = predictor::predict(&cfg2).unwrap();
        assert!(hi.grad_mib <= lo.grad_mib + 0.01, "case {case}");
        assert!(hi.opt_mib <= lo.opt_mib + 0.01, "case {case}");
        assert!(hi.param_mib <= lo.param_mib + 0.01, "case {case}");
    }
}

#[test]
fn trace_is_balanced_for_random_configs() {
    let mut r = Prng::new(1234);
    for case in 0..60 {
        let cfg = arb_config(&mut r);
        let pm = parser::parse(&cfg).unwrap();
        let events = simulator::trace::generate(&pm, &cfg);
        let mut live = std::collections::HashSet::new();
        for e in &events {
            match e {
                simulator::Event::Alloc { id, .. } => {
                    assert!(live.insert(*id), "case {case}: id reuse")
                }
                simulator::Event::Free { id } => assert!(live.remove(id), "case {case}: bad free"),
                simulator::Event::Phase { .. } => {}
            }
        }
        // replay must succeed and end with allocated == persistent only
        let replay = simulator::engine::replay(&events).unwrap();
        assert!(replay.stats.peak_allocated >= replay.stats.allocated);
    }
}

/// Draw a random trace with the dense-id invariant real traces have
/// (ids issued sequentially, so every id < number of events).
fn arb_trace(r: &mut Prng) -> Vec<simulator::Event> {
    use mmpredict::simulator::trace::ALL_TAGS;
    const PHASES: [&str; 4] = ["startup", "forward", "backward", "step"];
    let n_ops = r.range(50, 600);
    let mut events = Vec::new();
    let mut live: Vec<u64> = Vec::new();
    let mut next_id = 0u64;
    for _ in 0..n_ops {
        let roll = r.f64();
        if roll < 0.08 {
            events.push(simulator::Event::Phase { name: *r.pick(&PHASES) });
        } else if roll < 0.60 || live.is_empty() {
            let bytes = match r.range(0, 2) {
                0 => r.range(0, 4096) as u64, // includes 0-byte allocs
                1 => r.range(4096, 1 << 20) as u64,
                _ => r.range(1 << 20, 48 << 20) as u64,
            };
            let tag = *r.pick(&ALL_TAGS);
            events.push(simulator::Event::Alloc { id: next_id, bytes, tag });
            live.push(next_id);
            next_id += 1;
        } else {
            let idx = r.range(0, live.len() - 1);
            events.push(simulator::Event::Free { id: live.swap_remove(idx) });
        }
    }
    // free a random subset of the leftovers so persistent state varies
    while !live.is_empty() && r.chance(0.7) {
        let idx = r.range(0, live.len() - 1);
        events.push(simulator::Event::Free { id: live.swap_remove(idx) });
    }
    events
}

#[test]
fn dense_replay_matches_naive_reference() {
    use mmpredict::simulator::engine::{self, ReplayScratch, TimelineSink};
    use mmpredict::simulator::trace::ALL_TAGS;

    let mut r = Prng::new(0xD15EA5E);
    // one scratch reused across every case: proves reuse never leaks
    // state between replays
    let mut scratch = ReplayScratch::new();

    // randomized synthetic traces
    for case in 0..30 {
        let events = arb_trace(&mut r);
        let (naive, naive_tl) = engine::reference::replay_with_timeline(&events).unwrap();
        let mut sink = TimelineSink::every(1);
        let fast = engine::replay_with(&events, &mut scratch, &mut sink).unwrap();
        assert_eq!(fast, naive, "case {case}: Replay diverged");
        assert_eq!(sink.samples, naive_tl, "case {case}: timeline diverged");
        for &t in &ALL_TAGS {
            assert_eq!(fast.at_peak.get(t), naive.at_peak.get(t), "case {case} {t:?}");
            assert_eq!(fast.persistent.get(t), naive.persistent.get(t), "case {case} {t:?}");
        }
    }

    // real traces generated from random configurations
    for case in 0..25 {
        let cfg = arb_config(&mut r);
        let pm = parser::parse(&cfg).unwrap();
        let events = simulator::trace::generate(&pm, &cfg);
        let (naive, naive_tl) = engine::reference::replay_with_timeline(&events).unwrap();
        let mut sink = TimelineSink::every(1);
        let fast = engine::replay_with(&events, &mut scratch, &mut sink).unwrap();
        assert_eq!(fast, naive, "config case {case}: Replay diverged for {cfg:?}");
        assert_eq!(sink.samples, naive_tl, "config case {case}: timeline diverged");
    }
}

#[test]
fn parallel_sweep_matches_sequential_for_random_grids() {
    let mut r = Prng::new(0x5EED);
    for _case in 0..4 {
        let cfgs: Vec<TrainConfig> = (0..6).map(|_| arb_config(&mut r)).collect();
        let seq: Vec<f64> = cfgs
            .iter()
            .map(|c| simulator::simulate(c).unwrap().peak_mib)
            .collect();
        let par = mmpredict::sweep::Sweep::new(4).simulate_grid(&cfgs).unwrap();
        for (i, (m, want)) in par.iter().zip(&seq).enumerate() {
            assert_eq!(m.peak_mib, *want, "grid point {i}");
        }
    }
}

#[test]
fn allocator_fuzz_invariants() {
    let mut r = Prng::new(0xA110C);
    for _case in 0..30 {
        let mut a = CachingAllocator::new();
        let mut live = Vec::new();
        for _ in 0..400 {
            if live.is_empty() || r.chance(0.6) {
                let size = match r.range(0, 2) {
                    0 => r.range(1, 4096) as u64,               // small
                    1 => r.range(4096, 1 << 20) as u64,         // medium
                    _ => r.range(1 << 20, 64 << 20) as u64,     // large
                };
                live.push(a.alloc(size));
            } else {
                let idx = r.range(0, live.len() - 1);
                let h = live.swap_remove(idx);
                a.free(h);
            }
        }
        a.check_invariants();
        for h in live {
            a.free(h);
        }
        a.check_invariants();
        assert_eq!(a.stats().allocated, 0);
        assert!(a.stats().peak_reserved >= a.stats().peak_allocated);
    }
}

#[test]
fn feature_rows_finite_for_random_configs() {
    let mut r = Prng::new(31337);
    for _ in 0..60 {
        let cfg = arb_config(&mut r);
        let pm = parser::parse(&cfg).unwrap();
        let enc = parser::features::encode(&pm, &cfg);
        assert!(enc.features.iter().all(|v| v.is_finite() && *v >= 0.0));
        // padded request stays finite and inert
        let padded = enc.padded(1024).unwrap();
        assert_eq!(padded.len(), 1024 * parser::features::NUM_FEATURES);
    }
}

#[test]
fn toml_roundtrip_fuzz() {
    let mut r = Prng::new(555);
    for _ in 0..60 {
        let mbs = r.range(1, 64);
        let seq = r.range(16, 4096);
        let dp = r.range(1, 16);
        let text = format!(
            "model = \"llava-tiny\"\nmbs = {mbs}\nseq_len = {seq}\ndp = {dp}\nzero = {}\n",
            r.range(0, 3)
        );
        let cfg = TrainConfig::from_toml(&text).unwrap();
        assert_eq!(cfg.mbs, mbs as u64);
        assert_eq!(cfg.seq_len, seq as u64);
        assert_eq!(cfg.dp, dp as u64);
    }
}

/// Wire-path hardening: arbitrary strings — unicode, control
/// characters, quotes/backslashes, escape-looking content — must
/// round-trip emit → parse byte-identically, and the emitted document
/// must be a single NDJSON-safe line (no raw control bytes).
#[test]
fn json_string_roundtrip_fuzz() {
    use mmpredict::util::json_mini::{parse, Json};

    // character pool biased toward the nasty cases
    const POOL: &[char] = &[
        'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{0}', '\u{1}', '\u{8}',
        '\u{b}', '\u{c}', '\u{1b}', '\u{1f}', '\u{7f}', 'é', 'ß', '漢', '字', '🙂', '😀',
        '\u{ffff}', '\u{10000}',
    ];
    // multi-char fragments that *look* like JSON escapes or structure
    const FRAGMENTS: &[&str] = &["\\u0041", "\\\"", "\\\\n", "{\"k\":1}", "[1,2]", "\\ud83d"];

    let mut r = Prng::new(0x1A7E57);
    for case in 0..300 {
        let mut s = String::new();
        for _ in 0..r.range(0, 24) {
            if r.chance(0.2) {
                s.push_str(r.pick(FRAGMENTS));
            } else {
                s.push(*r.pick(POOL));
            }
        }
        // wrap into a document exercising keys and nesting too
        let doc = Json::Obj(
            [
                (s.clone(), Json::Str(s.clone())),
                ("arr".to_string(), Json::Arr(vec![Json::Str(s.clone()), Json::Null])),
            ]
            .into_iter()
            .collect(),
        );
        let text = doc.to_string();
        assert!(
            text.bytes().all(|b| b >= 0x20),
            "case {case}: raw control byte in emitted JSON for {s:?}: {text:?}"
        );
        let back = parse(&text).unwrap_or_else(|e| panic!("case {case}: {e:#} for {s:?}"));
        assert_eq!(back, doc, "case {case}: round-trip mismatch for {s:?}");
    }
}
