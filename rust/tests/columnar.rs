//! Differential test battery for the columnar multi-variant replay
//! engine: every lane of a columnar group replay must be
//! bitwise-identical to the scalar `replay_with` oracle *and* to the
//! naive `engine::reference` implementation — across fuzzed traces,
//! every zoo preset, every checked-in `examples/archs/*.toml` spec,
//! tp/pp per-rank stage views, and ZeRO stages 0-3. The incremental
//! baseline-vs-probe replayer and the planner's columnar/scalar A/B
//! (`--no-columnar` kill-switch) are proven equivalent the same way.

use mmpredict::config::{TrainConfig, ZeroStage};
use mmpredict::model::zoo;
use mmpredict::parser;
use mmpredict::planner::{self, Axes, Plan, PlanRequest};
use mmpredict::simulator::columnar::{
    divergence_event, interleave, replay_lanes, Incremental, Skeleton,
};
use mmpredict::simulator::{engine, trace, Event};
use mmpredict::sweep::{columnar, Sweep};
use mmpredict::util::Prng;

/// Group the given traces by skeleton, replay each group through the
/// columnar engine, and assert every lane matches both oracles exactly.
/// Returns (groups, lanes) for sharing sanity checks.
fn battery(traces: &[Vec<Event>], label: &str) -> (usize, usize) {
    let mut groups: Vec<(Skeleton, Vec<Vec<u64>>, Vec<usize>)> = Vec::new();
    for (i, t) in traces.iter().enumerate() {
        let (skel, sizes) = Skeleton::extract(t).unwrap();
        match groups.iter().position(|(s, _, _)| s.same_shape(&skel)) {
            Some(gi) => {
                groups[gi].1.push(sizes);
                groups[gi].2.push(i);
            }
            None => groups.push((skel, vec![sizes], vec![i])),
        }
    }
    for (skel, cols, idxs) in &groups {
        let table = interleave(cols);
        let group = replay_lanes(skel, &table, cols.len());
        assert!(group.stats.engine_ops <= group.stats.scalar_ops, "{label}");
        for (lane, &ti) in idxs.iter().enumerate() {
            let scalar = engine::replay(&traces[ti]).unwrap();
            let naive = engine::reference::replay(&traces[ti]).unwrap();
            assert_eq!(scalar, naive, "{label}: trace {ti}: scalar vs reference");
            assert_eq!(
                group.replays[lane], scalar,
                "{label}: trace {ti}: columnar lane vs scalar oracle"
            );
            for &t in &trace::ALL_TAGS {
                assert_eq!(
                    group.replays[lane].at_peak.get(t),
                    scalar.at_peak.get(t),
                    "{label}: trace {ti} tag {t:?}"
                );
            }
        }
    }
    (groups.len(), traces.len())
}

/// Random trace *family*: one structure, `n_lanes` size columns. Some
/// alloc sizes are shared by every lane (prefix sharing), some vary per
/// lane (divergence points), and the last lane duplicates lane 0
/// (dedupe).
fn arb_lane_traces(r: &mut Prng, n_lanes: usize) -> Vec<Vec<Event>> {
    const PHASES: [&str; 4] = ["startup", "forward", "backward", "step"];
    fn draw_size(r: &mut Prng) -> u64 {
        match r.range(0, 2) {
            0 => r.range(0, 4096) as u64, // includes 0-byte allocs
            1 => r.range(4096, 1 << 20) as u64,
            _ => r.range(1 << 20, 48 << 20) as u64,
        }
    }
    let n_ops = r.range(40, 300);
    let mut traces = vec![Vec::new(); n_lanes - 1];
    let mut live: Vec<u64> = Vec::new();
    let mut next_id = 0u64;
    for _ in 0..n_ops {
        let roll = r.f64();
        if roll < 0.08 {
            let name = *r.pick(&PHASES);
            for t in &mut traces {
                t.push(Event::Phase { name });
            }
        } else if roll < 0.60 || live.is_empty() {
            let tag = *r.pick(&trace::ALL_TAGS);
            // shared size (class stays merged) or per-lane divergence
            let shared = r.chance(0.55).then(|| draw_size(r));
            for t in &mut traces {
                let bytes = shared.unwrap_or_else(|| draw_size(r));
                t.push(Event::Alloc { id: next_id, bytes, tag });
            }
            live.push(next_id);
            next_id += 1;
        } else {
            let idx = r.range(0, live.len() - 1);
            let id = live.swap_remove(idx);
            for t in &mut traces {
                t.push(Event::Free { id });
            }
        }
    }
    while !live.is_empty() && r.chance(0.7) {
        let idx = r.range(0, live.len() - 1);
        let id = live.swap_remove(idx);
        for t in &mut traces {
            t.push(Event::Free { id });
        }
    }
    traces.push(traces[0].clone());
    traces
}

fn tiny(model: &str) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        mbs: 2,
        seq_len: 64,
        ..TrainConfig::llava_finetune_default()
    }
}

#[test]
fn fuzzed_lane_groups_match_both_oracles() {
    let mut r = Prng::new(0xC01_5EED);
    for case in 0..25 {
        let n_lanes = r.range(2, 9);
        let traces = arb_lane_traces(&mut r, n_lanes);
        let (groups, lanes) = battery(&traces, &format!("fuzz case {case}"));
        // every lane shares the structure: exactly one group
        assert_eq!(groups, 1, "fuzz case {case}");
        assert_eq!(lanes, n_lanes, "fuzz case {case}");
    }
}

#[test]
fn zoo_presets_zero0_to_3_match_both_oracles() {
    for name in zoo::names() {
        let mut traces = Vec::new();
        let base = TrainConfig { mbs: 1, seq_len: 256, ..tiny(name) };
        let pm = parser::parse(&base).unwrap();
        for dp in [1u64, 4] {
            for zero in [ZeroStage::Zero0, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3] {
                let cfg = TrainConfig { dp, zero, ..base.clone() };
                traces.push(trace::generate(&pm, &cfg));
            }
        }
        let (groups, lanes) = battery(&traces, name);
        // dp/zero only change sizes within a fixed structure family, so
        // the 8 variants collapse into a handful of skeleton groups
        assert!(groups < lanes, "{name}: {groups} groups for {lanes} lanes");
    }
}

#[test]
fn arch_toml_specs_match_both_oracles() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/archs");
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .expect("examples/archs directory")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    paths.sort();
    assert!(paths.len() >= 3, "expected >=3 checked-in specs");
    for path in paths {
        let base = TrainConfig {
            seq_len: 4096,
            mbs: 2,
            ..tiny(path.to_str().unwrap())
        };
        let pm = parser::parse(&base).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        let mut traces = Vec::new();
        for dp in [1u64, 8] {
            for zero in [ZeroStage::Zero0, ZeroStage::Zero2, ZeroStage::Zero3] {
                let cfg = TrainConfig { dp, zero, ..base.clone() };
                traces.push(trace::generate(&pm, &cfg));
            }
        }
        battery(&traces, path.to_str().unwrap());
    }
}

#[test]
fn tp_pp_stage_view_lanes_match_both_oracles() {
    // per-rank stage views: each pipeline stage's trace is its own lane
    let mut traces = Vec::new();
    for tp in [1u64, 2] {
        for pp in [1u64, 2, 4] {
            let cfg = TrainConfig { tp, pp, ..tiny("llava-tiny") };
            let pm = parser::parse(&cfg).unwrap();
            if pp <= 1 {
                traces.push(trace::generate(&pm, &cfg));
                continue;
            }
            for (s, &b) in parser::pipeline::stage_bounds(&pm, pp).unwrap().iter().enumerate() {
                let view = parser::pipeline::stage_view(&pm, b, parser::pipeline::in_flight(pp, s));
                traces.push(trace::generate(&view, &cfg));
            }
        }
    }
    battery(&traces, "tp/pp stage views");
}

#[test]
fn columnar_sweep_matches_scalar_sweep_on_parallelism_grid() {
    // Measurement-level equivalence across tp/pp/zero, including the
    // binding-stage fold for pp > 1.
    let mut cfgs = Vec::new();
    for tp in [1u64, 2] {
        for pp in [1u64, 2] {
            for zero in [ZeroStage::Zero0, ZeroStage::Zero2] {
                cfgs.push(TrainConfig { tp, pp, zero, dp: 2, ..tiny("llava-tiny") });
            }
        }
    }
    let scalar = Sweep::new(2).with_columnar(false).simulate_grid(&cfgs).unwrap();
    for threads in [1usize, 4] {
        let cols = columnar::simulate_grid(&cfgs, threads).unwrap();
        for (i, (c, s)) in cols.iter().zip(&scalar).enumerate() {
            assert_eq!(c, s, "grid point {i} at {threads} threads");
        }
    }
}

#[test]
fn incremental_random_config_pairs_match_from_scratch() {
    let mut r = Prng::new(0xD1FF);
    let base_pool: [(u64, ZeroStage); 4] = [
        (2, ZeroStage::Zero2),
        (8, ZeroStage::Zero2),
        (4, ZeroStage::Zero3),
        (2, ZeroStage::Zero0),
    ];
    for case in 0..30 {
        let (dp_a, zero_a) = *r.pick(&base_pool);
        let (dp_b, zero_b) = *r.pick(&base_pool);
        let mut a = tiny(*r.pick(&["llava-tiny", "llama-tiny"]));
        a.mbs = r.range(1, 8) as u64;
        a.dp = dp_a;
        a.zero = zero_a;
        let mut b = a.clone();
        b.dp = dp_b;
        b.zero = zero_b;
        if r.chance(0.4) {
            b.mbs = r.range(1, 8) as u64;
        }
        let ta = trace::generate(&parser::parse(&a).unwrap(), &a);
        let tb = trace::generate(&parser::parse(&b).unwrap(), &b);
        let inc = Incremental::new(&ta, r.range(5, 64)).unwrap();
        assert_eq!(*inc.base(), engine::replay(&ta).unwrap(), "case {case}: baseline");

        let (skel_a, _) = Skeleton::extract(&ta).unwrap();
        let (skel_b, _) = Skeleton::extract(&tb).unwrap();
        if !skel_a.same_shape(&skel_b) {
            // structural divergence must be an error, not a wrong answer
            assert!(inc.replay(&tb).is_err(), "case {case}");
            continue;
        }
        let (replay, div) = inc.replay(&tb).unwrap();
        assert_eq!(replay, engine::replay(&tb).unwrap(), "case {case}: probe replay");
        // divergence point == first differing event, by brute force
        let want = ta.iter().zip(&tb).position(|(x, y)| x != y);
        assert_eq!(div, want, "case {case}: divergence index");
    }
}

#[test]
fn incremental_degenerate_cases() {
    let cfg = tiny("llava-tiny");
    let t = trace::generate(&parser::parse(&cfg).unwrap(), &cfg);
    let inc = Incremental::new(&t, 16).unwrap();

    // identical probe: cached result, no divergence
    let (replay, div) = inc.replay(&t).unwrap();
    assert_eq!(div, None);
    assert_eq!(replay, *inc.base());

    // everything differs: divergence at the very first alloc event
    let scaled: Vec<Event> = t
        .iter()
        .map(|ev| match *ev {
            Event::Alloc { id, bytes, tag } => Event::Alloc { id, bytes: bytes * 2 + 512, tag },
            other => other,
        })
        .collect();
    let (replay, div) = inc.replay(&scaled).unwrap();
    assert_eq!(replay, engine::replay(&scaled).unwrap());
    let first_alloc = t.iter().position(|e| matches!(e, Event::Alloc { .. }));
    assert_eq!(div, first_alloc);
    let (skel, sa) = Skeleton::extract(&t).unwrap();
    let (_, sb) = Skeleton::extract(&scaled).unwrap();
    assert_eq!(divergence_event(&skel, &sa, &sb), first_alloc);
}

fn frontier_fingerprint(plan: &Plan) -> Vec<(String, u64, f64, f64, f64, bool, bool, usize)> {
    plan.candidates
        .iter()
        .map(|c| {
            (
                c.cfg.cache_key(),
                c.cfg.mbs,
                c.predicted_mib,
                c.simulated_mib,
                c.headroom_mib,
                c.frontier_open,
                c.dominated,
                c.binding_stage,
            )
        })
        .collect()
}

#[test]
fn planner_frontier_identical_columnar_on_vs_off() {
    let base = TrainConfig { model: "llava-1.5-7b".into(), ..TrainConfig::llava_finetune_default() };
    let req = PlanRequest {
        base: base.clone(),
        budget_mib: 80.0 * 1024.0,
        axes: Axes {
            mbs: vec![1, 2, 4, 8],
            seq_len: vec![2048],
            dp: vec![4, 8],
            zero: vec![ZeroStage::Zero2, ZeroStage::Zero3],
            ..Axes::fixed(&base)
        },
    };
    let on = planner::plan_with(&req, &Sweep::new(2).with_columnar(true)).unwrap();
    let off = planner::plan_with(&req, &Sweep::new(2).with_columnar(false)).unwrap();
    assert!(!on.candidates.is_empty(), "7b grid should have a frontier under 80 GiB");
    assert_eq!(
        frontier_fingerprint(&on),
        frontier_fingerprint(&off),
        "frontier must be config-for-config identical with columnar on vs off"
    );
    // identical measurements -> identical bisection path and escalations
    assert_eq!(on.stats.sim_points, off.stats.sim_points);
    assert_eq!(on.stats.branches, off.stats.branches);
    for (a, b) in on.candidates.iter().zip(&off.candidates) {
        match (&a.escalation, &b.escalation) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.mbs, y.mbs);
                assert_eq!(x.simulated_mib, y.simulated_mib);
            }
            _ => panic!("escalation mismatch for {}", a.cfg.cache_key()),
        }
    }
}

#[test]
fn env_kill_switch_controls_default_engine() {
    // Sweep::new derives its default from REPRO_NO_COLUMNAR; the
    // builder always wins. (No env mutation here — tests run threaded.)
    let engine = Sweep::new(1);
    assert_eq!(engine.columnar(), mmpredict::sweep::default_columnar());
    assert!(!Sweep::new(1).with_columnar(false).columnar());
    assert!(Sweep::new(1).with_columnar(true).columnar());
}
