//! Checked-in architecture specs can never rot: every
//! `examples/archs/*.toml` round-trips through parse → lower →
//! predict → simulate, and the IR-only architectures run end-to-end
//! through the sweep engine and the capacity planner with no Rust code
//! changes.

use mmpredict::config::TrainConfig;
use mmpredict::model::arch::ArchSpec;
use mmpredict::model::layer::AttnImpl;
use mmpredict::model::Modality;
use mmpredict::planner::{Axes, PlanRequest};
use mmpredict::{parser, planner, predictor, report, simulator, sweep};

fn archs_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/archs")
}

fn spec_paths() -> Vec<std::path::PathBuf> {
    let mut out: Vec<_> = std::fs::read_dir(archs_dir())
        .expect("examples/archs directory")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    out.sort();
    assert!(out.len() >= 3, "expected >=3 checked-in specs, found {}", out.len());
    out
}

fn cfg_for(path: &std::path::Path) -> TrainConfig {
    TrainConfig {
        model: path.to_str().unwrap().to_string(),
        // long enough for 4x576 projected image tokens or 1500 audio
        // tokens plus text
        seq_len: 4096,
        mbs: 2,
        dp: 2,
        ..TrainConfig::llava_finetune_default()
    }
}

#[test]
fn every_checked_in_spec_round_trips_to_a_prediction() {
    for path in spec_paths() {
        let spec = ArchSpec::from_file(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        let entry = spec
            .lower(4096, AttnImpl::Flash)
            .unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        assert!(entry.spec.param_elems() > 0, "{path:?}");
        assert!(entry.spec.num_layers() > 10, "{path:?}");

        let cfg = cfg_for(&path);
        let pm = parser::parse(&cfg).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        assert_eq!(pm.model_name, spec.name, "{path:?}: ParsedModel carries the spec name");

        let p = predictor::predict(&cfg).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        assert!(p.peak_mib > 0.0 && p.peak_mib.is_finite(), "{path:?}");
        let m = simulator::simulate(&cfg).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        let ape = report::ape(p.peak_mib as f64, m.peak_mib);
        assert!(ape < 0.5, "{path:?}: predictor vs simulator APE {ape:.2}");
    }
}

#[test]
fn audio_lang_spec_has_an_audio_branch_frozen_under_finetune() {
    let path = archs_dir().join("audio-lang.toml");
    let cfg = cfg_for(&path);
    let pm = parser::parse(&cfg).unwrap();
    let audio: Vec<_> = pm.layers.iter().filter(|l| l.modality == Modality::Audio).collect();
    assert!(!audio.is_empty(), "audio tower present");
    // finetune trains connector + decoder; the audio tower stays frozen
    // and (being upstream of the trainable connector) retains only its
    // boundary layer.
    assert!(audio.iter().all(|l| !l.trainable));
    let (boundary, interior) = audio.split_last().unwrap();
    assert!(boundary.on_bwd_path);
    assert!(interior.iter().all(|l| !l.on_bwd_path));
    assert!(pm.layers.iter().any(|l| l.modality == Modality::Projector && l.trainable));
}

#[test]
fn three_tower_spec_has_independent_streams() {
    let path = archs_dir().join("three-tower.toml");
    let mut cfg = cfg_for(&path);
    cfg.images_per_sample = 2;
    cfg.clips_per_sample = 1;
    let pm = parser::parse(&cfg).unwrap();
    for m in [Modality::Vision, Modality::Audio, Modality::Projector, Modality::Language] {
        assert!(pm.layers.iter().any(|l| l.modality == m), "{m:?} layers present");
    }
    // vision stream scales with images_per_sample, audio with clips
    let vision = pm.token_ctx.tokens("vision_tower", Modality::Vision);
    assert_eq!(vision, cfg.mbs * 2 * 577);
    let audio = pm.token_ctx.tokens("audio_tower", Modality::Audio);
    assert_eq!(audio, cfg.mbs * 1500);
    // two connectors, each with its own stream
    assert_eq!(pm.token_ctx.streams.len(), 4);
}

#[test]
fn interleave_spec_bakes_four_images_per_sample() {
    let path = archs_dir().join("llava-interleave.toml");
    let cfg = cfg_for(&path); // config still says images_per_sample = 1
    let pm = parser::parse(&cfg).unwrap();
    assert_eq!(pm.token_ctx.tokens("vision_tower", Modality::Vision), cfg.mbs * 4 * 577);
    assert_eq!(pm.token_ctx.tokens("mm_projector", Modality::Projector), cfg.mbs * 4 * 576);
}

#[test]
fn qwen_spec_merges_the_patch_grid() {
    let path = archs_dir().join("qwen2vl-ish.toml");
    let cfg = cfg_for(&path);
    let pm = parser::parse(&cfg).unwrap();
    // 448/14 = 32x32 = 1024 patches, merged 2x2 -> 256 connector tokens
    assert_eq!(pm.token_ctx.tokens("visual", Modality::Vision), cfg.mbs * 1025);
    assert_eq!(pm.token_ctx.tokens("merger", Modality::Projector), cfg.mbs * 256);
}

#[test]
fn spec_files_run_through_the_sweep_engine() {
    let path = archs_dir().join("audio-lang.toml");
    let base = cfg_for(&path);
    let cfgs: Vec<TrainConfig> = [1u64, 2, 4]
        .iter()
        .map(|&dp| TrainConfig { dp, ..base.clone() })
        .collect();
    let engine = sweep::Sweep::new(2);
    let rows = engine
        .run(&cfgs, |ctx, pm, cfg| {
            let p = predictor::predict(cfg)?.peak_mib as f64;
            let m = ctx.simulate_parsed(pm, cfg)?.peak_mib;
            Ok((p, m))
        })
        .unwrap();
    assert_eq!(rows.len(), 3);
    for (p, m) in &rows {
        assert!(*p > 0.0 && *m > 0.0);
    }
    // ZeRO-2: per-GPU peak shrinks with DP
    assert!(rows[2].1 < rows[0].1);
}

#[test]
fn spec_files_run_through_the_planner() {
    let path = archs_dir().join("three-tower.toml");
    let base = cfg_for(&path);
    let req = PlanRequest {
        axes: Axes {
            mbs: vec![1, 2, 4],
            seq_len: vec![4096],
            dp: vec![2],
            ..Axes::standard(&base)
        },
        base,
        budget_mib: 80.0 * 1024.0,
    };
    let plan = planner::plan(&req).unwrap();
    // every simulator-validated recommendation is within budget
    for c in plan.recommended() {
        assert!(c.simulated_mib <= req.budget_mib);
        assert!(c.cfg.model.ends_with("three-tower.toml"));
    }
}

#[test]
fn spec_files_serve_through_the_prediction_service() {
    use mmpredict::coordinator::{PredictionService, ServiceConfig};
    let svc = PredictionService::start_analytical(ServiceConfig::default());
    let cfg = cfg_for(&archs_dir().join("qwen2vl-ish.toml"));
    let direct = predictor::predict(&cfg).unwrap();
    let served = svc.predict(cfg).unwrap();
    assert_eq!(served.peak_mib, direct.peak_mib);
    svc.shutdown();
}

#[test]
fn predict_prints_a_modality_split_for_multi_tower_models() {
    let path = archs_dir().join("three-tower.toml");
    let cfg = cfg_for(&path);
    let pm = parser::parse(&cfg).unwrap();
    let rendered = report::modality_table(&pm).render();
    for label in ["vision", "audio", "connector", "language"] {
        assert!(rendered.contains(label), "missing {label} in:\n{rendered}");
    }
    let shares = report::modality_split(&pm);
    assert_eq!(shares.len(), 4);
    // the audio tower is off the backward path under finetune except
    // its boundary — its activation share must be far below the
    // decoder's
    let act = |m: Modality| {
        shares.iter().find(|s| s.modality == m).map(|s| s.act_mib).unwrap_or(0.0)
    };
    assert!(act(Modality::Audio) < act(Modality::Language) * 0.5);
}
