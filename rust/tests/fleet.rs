//! Fleet what-if oracle: packing invariants (no device above its
//! predicted capacity, exact stranded-memory accounting), placement
//! determinism across worker-thread counts, the admit/replan wire
//! round-trips with strict unknown-field rejection, and the
//! heterogeneous demo fleet end-to-end with simulator-validated
//! placements. Runs entirely on the analytical backend.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use mmpredict::api::{self, codec, ApiRequest, ApiResponse, ErrorCode, FleetParams, Method};
use mmpredict::config::TrainConfig;
use mmpredict::coordinator::{PredictionService, ServiceConfig};
use mmpredict::fleet::{self, FleetAction};
use mmpredict::sweep::Sweep;
use mmpredict::util::json_mini::Json;

fn tiny_job(name: &str, mbs: u64) -> (String, TrainConfig) {
    (
        name.to_string(),
        TrainConfig {
            model: "llava-tiny".to_string(),
            mbs,
            seq_len: 128,
            dp: 1,
            ..TrainConfig::llava_finetune_default()
        },
    )
}

fn start_server() -> api::serve::Server {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let svc = PredictionService::start_analytical(ServiceConfig::default());
    api::serve::serve(
        listener,
        svc,
        &api::serve::ServeOptions { conn_threads: 4, ..Default::default() },
    )
    .expect("server start")
}

struct WireClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl WireClient {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(60)))
            .unwrap();
        WireClient {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn call_raw(&mut self, line: &str) -> ApiResponse {
        writeln!(self.writer, "{line}").expect("write");
        self.writer.flush().expect("flush");
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp).expect("read");
        assert!(n > 0, "server closed the connection");
        ApiResponse::parse_line(resp.trim()).expect("well-formed v1 response")
    }

    fn call(&mut self, req: &ApiRequest) -> ApiResponse {
        self.call_raw(&req.to_json().to_string())
    }
}

/// Per-device packing invariant plus exact stranded accounting: with
/// integer-MiB rank demands and integer-MiB capacities, `used +
/// stranded == capacity` holds with `==`, not a tolerance, on every
/// device and in the totals.
#[test]
fn no_device_packs_above_capacity_and_accounting_is_exact() {
    let engine = Sweep::new(2);
    let jobs: Vec<_> = (0..6).map(|i| tiny_job(&format!("j{i}"), 1 + i % 3)).collect();
    let r = fleet::what_if(
        &[("a100-40g".to_string(), 2), ("a100-80g".to_string(), 1)],
        &jobs,
        &FleetAction::Pack,
        &engine,
        true,
    )
    .unwrap();
    assert_eq!(r.placements.len() + r.rejected.len(), jobs.len());
    for d in &r.devices {
        assert!(d.used_mib <= d.device.capacity_mib, "{} over capacity", d.device.id);
        assert!(d.used_mib >= 0.0 && d.stranded_mib >= 0.0);
        assert_eq!(d.used_mib + d.stranded_mib, d.device.capacity_mib, "{}", d.device.id);
        assert_eq!(d.used_mib, d.used_mib.trunc(), "quantized to whole MiB");
    }
    assert_eq!(r.total_used_mib() + r.total_stranded_mib(), r.total_capacity_mib());
    // every assignment's MiB sums back to the device ledger
    let placed: f64 = r
        .placements
        .iter()
        .flat_map(|p| p.assignments.iter().map(|a| a.mib))
        .sum();
    assert_eq!(placed, r.total_used_mib());
}

/// The oracle is deterministic: the full JSON report is byte-identical
/// whether predictions/simulations ran on 1 or 8 worker threads.
#[test]
fn placement_is_deterministic_across_thread_counts() {
    let jobs: Vec<_> = (0..8).map(|i| tiny_job(&format!("j{i}"), 1 + i % 4)).collect();
    let devices = [("a100-40g".to_string(), 2), ("h100-80g".to_string(), 1)];
    let run = |threads: usize| {
        let engine = Sweep::new(threads);
        let r = fleet::what_if(&devices, &jobs, &FleetAction::Pack, &engine, true).unwrap();
        codec::fleet_report_to_json(&r).to_string()
    };
    let one = run(1);
    assert_eq!(one, run(8), "thread count changed the fleet report");
    assert_eq!(one, run(3));
}

/// The heterogeneous 12-job demo fleet end-to-end: every accepted
/// placement carries simulator ground truth, the queue partitions into
/// placements + rejections, and the sub-GiB tiny jobs always place.
#[test]
fn demo_fleet_places_with_simulator_validation() {
    let engine = Sweep::new(mmpredict::sweep::default_threads());
    let jobs = fleet::demo_jobs();
    assert!(jobs.len() >= 10, "demo queue is the >=10-job acceptance fleet");
    let r = fleet::what_if(&fleet::demo_devices(), &jobs, &FleetAction::Pack, &engine, true)
        .unwrap();
    assert!(r.validated);
    assert_eq!(r.placements.len() + r.rejected.len(), jobs.len());
    assert!(!r.placements.is_empty());
    for p in &r.placements {
        let sim = p.simulated_peak_mib.expect("validated placements carry ground truth");
        assert!(sim > 0.0, "{}", p.job);
        assert!(p.per_rank_peak_mib > 0.0);
        assert!(!p.assignments.is_empty());
    }
    for name in ["tiny-a", "tiny-b", "llama-tiny-a"] {
        assert!(r.placement(name).is_some(), "tiny job {name} must always fit");
    }
    // rejected jobs explain themselves
    for rej in &r.rejected {
        assert!(!rej.reason.is_empty(), "{}", rej.job);
    }
}

/// `admit` over the wire: the envelope round-trips through the slow
/// admission tier, answers the verdict, and the response is additive
/// (action/admitted/validated/totals all present).
#[test]
fn admit_round_trips_over_the_wire() {
    let server = start_server();
    let mut client = WireClient::connect(server.addr());

    let req = ApiRequest::new(
        "adm",
        Method::Fleet(FleetParams {
            devices: vec![("a100-40g".into(), 2)],
            jobs: vec![tiny_job("a", 1), tiny_job("b", 2), tiny_job("cand", 1)],
            action: FleetAction::Admit("cand".into()),
        }),
    );
    let resp = client.call(&req);
    assert_eq!(resp.id.as_deref(), Some("adm"));
    let payload = resp.result.expect("admit");
    assert_eq!(payload.get("action").unwrap().as_str(), Some("admit"));
    assert_eq!(payload.get("admitted"), Some(&Json::Bool(true)));
    assert!(matches!(payload.get("validated"), Some(Json::Bool(true))));
    let placements = payload.get("placements").unwrap().as_arr().unwrap();
    assert_eq!(placements.len(), 3);
    let cand = placements
        .iter()
        .find(|p| p.get("job").unwrap().as_str() == Some("cand"))
        .expect("candidate placed");
    assert_eq!(cand.get("replanned"), Some(&Json::Bool(false)));
    assert!(cand.get("simulated_peak_mib").unwrap().as_f64().unwrap() > 0.0);

    // the wire answer equals the library answer, field for field
    let engine = Sweep::new(1);
    let lib = fleet::what_if(
        &[("a100-40g".to_string(), 2)],
        &[tiny_job("a", 1), tiny_job("b", 2), tiny_job("cand", 1)],
        &FleetAction::Admit("cand".into()),
        &engine,
        true,
    )
    .unwrap();
    assert_eq!(codec::fleet_report_to_json(&lib).to_string(), payload.to_string());
    server.shutdown();
}

/// `replan` over the wire: the OOM-signalled job's as-specified config
/// is never re-placed verbatim — it either lands via a different
/// frontier config (`replanned: true`) or is rejected with
/// alternatives.
#[test]
fn replan_evicts_the_as_specified_config() {
    let server = start_server();
    let mut client = WireClient::connect(server.addr());
    let jobs = vec![tiny_job("a", 1), tiny_job("oomed", 2)];
    let original = jobs[1].1.clone();
    let req = ApiRequest::new(
        "rp",
        Method::Fleet(FleetParams {
            devices: vec![("a100-40g".into(), 1)],
            jobs,
            action: FleetAction::Replan("oomed".into()),
        }),
    );
    let payload = client.call(&req).result.expect("replan");
    assert_eq!(payload.get("action").unwrap().as_str(), Some("replan"));
    let admitted = match payload.get("admitted") {
        Some(Json::Bool(b)) => *b,
        other => panic!("admitted must be a bool, got {other:?}"),
    };
    let placements = payload.get("placements").unwrap().as_arr().unwrap();
    let target = placements
        .iter()
        .find(|p| p.get("job").unwrap().as_str() == Some("oomed"));
    if admitted {
        let p = target.expect("admitted implies placed");
        assert_eq!(p.get("replanned"), Some(&Json::Bool(true)));
        // the placed config differs from the OOM-signalled one
        let placed = codec::config_from_json(p.get("config").unwrap()).unwrap();
        assert_ne!(placed.cache_key(), original.cache_key());
    } else {
        assert!(target.is_none());
        let rejected = payload.get("rejected").unwrap().as_arr().unwrap();
        assert!(rejected
            .iter()
            .any(|r| r.get("job").unwrap().as_str() == Some("oomed")));
    }
    server.shutdown();
}

/// Strict request decoding: unknown params/device/job fields, unknown
/// actions, a `job` with `pack`, and unknown device kinds are all
/// structured bad_requests that never kill the connection.
#[test]
fn fleet_requests_are_strict() {
    let server = start_server();
    let mut client = WireClient::connect(server.addr());
    let cfg = r#"{"model":"llava-tiny","mbs":1,"seq_len":128}"#;
    let cases: Vec<(String, &str)> = vec![
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g"}}],"jobz":[{{"name":"a","config":{cfg}}}]}}}}"#
            ),
            "jobz",
        ),
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g","slots":2}}],"jobs":[{{"name":"a","config":{cfg}}}]}}}}"#
            ),
            "slots",
        ),
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g"}}],"jobs":[{{"name":"a","config":{cfg},"priority":9}}]}}}}"#
            ),
            "priority",
        ),
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g"}}],"jobs":[{{"name":"a","config":{cfg}}}],"action":"defrag"}}}}"#
            ),
            "defrag",
        ),
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g"}}],"jobs":[{{"name":"a","config":{cfg}}}],"job":"a"}}}}"#
            ),
            "job",
        ),
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g"}}],"jobs":[{{"name":"a","config":{cfg}}}],"action":"admit"}}}}"#
            ),
            "admit",
        ),
        // out-of-range counts are rejected at decode time, before any
        // expansion work — a huge count must be a structured error,
        // never an allocation storm on the worker
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g","count":0}}],"jobs":[{{"name":"a","config":{cfg}}}]}}}}"#
            ),
            "count",
        ),
        (
            format!(
                r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-40g","count":999999999999999}}],"jobs":[{{"name":"a","config":{cfg}}}]}}}}"#
            ),
            "between 1 and 1024",
        ),
    ];
    for (line, needle) in &cases {
        let err = client.call_raw(line).result.unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest, "{needle}: {}", err.message);
        assert!(err.message.contains(needle), "{needle} not named: {}", err.message);
    }
    // unknown device kind is a structured error too (did-you-mean)
    let line = format!(
        r#"{{"v":1,"id":"x","method":"fleet","params":{{"devices":[{{"kind":"a100-90g"}}],"jobs":[{{"name":"a","config":{cfg}}}]}}}}"#
    );
    let err = client.call_raw(&line).result.unwrap_err();
    assert!(err.message.contains("unknown device kind"), "{}", err.message);
    // and the connection still serves after every rejection
    let ok = client.call_raw(&format!(
        r#"{{"v":1,"id":"ok","method":"fleet","params":{{"devices":[{{"kind":"a100-40g"}}],"jobs":[{{"name":"a","config":{cfg}}}]}}}}"#
    ));
    assert!(ok.result.is_ok());
    server.shutdown();
}

/// Concurrent fleet queries from several connections answer
/// byte-identically — the oracle has no hidden shared state.
#[test]
fn concurrent_fleet_queries_agree() {
    let server = start_server();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr);
                let mut outs = Vec::new();
                for round in 0..3 {
                    let req = ApiRequest::new(
                        format!("c{i}-{round}"),
                        Method::Fleet(FleetParams {
                            devices: vec![("a100-40g".into(), 2), ("mi300-192g".into(), 1)],
                            jobs: vec![tiny_job("a", 1), tiny_job("b", 2), tiny_job("c", 4)],
                            action: FleetAction::Pack,
                        }),
                    );
                    outs.push(client.call(&req).result.expect("fleet").to_string());
                }
                outs
            })
        })
        .collect();
    let all: Vec<String> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client thread"))
        .collect();
    assert!(all.windows(2).all(|w| w[0] == w[1]), "fleet answers diverged");
    server.shutdown();
}
