//! Parallelism invariant suite: property tests over every zoo preset
//! and every checked-in architecture spec (`examples/archs/*.toml`),
//! driven by the deterministic [`mmpredict::util::prng::Prng`] fuzzer.
//!
//! The invariants (ARCHITECTURE.md §Parallelism):
//!
//! 1. per-rank weight/grad/optimizer terms — and hence the peak — are
//!    non-increasing in the tensor-parallel degree `tp`;
//! 2. for `pp > 1`, the per-rank peak (max over pipeline stages) never
//!    exceeds the single-device peak, and the stage views partition
//!    the weights exactly;
//! 3. ZeRO-3 + dp shards divide the optimizer state exactly: per-rank
//!    state is `ceil(T/dp)` elements, and (for power-of-two dp) the
//!    predictor's optimizer term scales *bitwise* by `1/dp`;
//! 4. `tp = pp = dp = 1` runs the byte-identical single-device code
//!    path (the golden parity fixtures in `tests/parity.rs` pin those
//!    numbers; here we pin that the per-rank APIs degenerate to them).

use mmpredict::config::{Precision, Stage, TrainConfig, ZeroStage};
use mmpredict::parser::{self, pipeline};
use mmpredict::predictor::{self, Prediction};
use mmpredict::simulator::{self, zero};
use mmpredict::util::prng::Prng;

/// Every model reference the suite fuzzes over: the zoo registry plus
/// every checked-in architecture spec.
fn all_models() -> Vec<String> {
    let mut models: Vec<String> = mmpredict::zoo::names()
        .into_iter()
        .map(str::to_string)
        .collect();
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/archs");
    let mut specs: Vec<String> = std::fs::read_dir(dir)
        .expect("examples/archs exists")
        .filter_map(|e| e.ok())
        .map(|e| e.path().to_string_lossy().into_owned())
        .filter(|p| p.ends_with(".toml"))
        .collect();
    specs.sort();
    models.extend(specs);
    models
}

/// A random small-but-valid config for `model` (LoRA excluded: spec
/// files name their decoders freely, so the default target list can
/// legitimately refuse to apply).
fn random_cfg(rng: &mut Prng, model: &str) -> TrainConfig {
    let stage = *rng.pick(&[Stage::Pretrain, Stage::Finetune, Stage::Full]);
    TrainConfig {
        model: model.to_string(),
        stage,
        mbs: *rng.pick(&[1u64, 2, 4]),
        seq_len: *rng.pick(&[64u64, 128, 256]),
        dp: *rng.pick(&[1u64, 2, 4, 8]),
        zero: *rng.pick(&[ZeroStage::Zero0, ZeroStage::Zero1, ZeroStage::Zero2, ZeroStage::Zero3]),
        precision: *rng.pick(&[Precision::Bf16Mixed, Precision::Fp32]),
        grad_checkpoint: rng.chance(0.7),
        lora: None,
        ..TrainConfig::llava_finetune_default()
    }
}

/// Invariant 1: weight/grad/optimizer terms (and the peak they anchor)
/// are non-increasing in tp. Exact in f32 — every per-layer element
/// count is `div_ceil`-monotone and f32 add/mul/max are monotone — so
/// the slack is a pure guard against platform-float surprises.
#[test]
fn tp_weight_grad_opt_terms_non_increasing() {
    let mut rng = Prng::new(0xA11CE);
    for model in all_models() {
        for _case in 0..2 {
            let base = random_cfg(&mut rng, &model);
            let mut prev: Option<Prediction> = None;
            for tp in [1u64, 2, 4, 8] {
                let mut cfg = base.clone();
                cfg.tp = tp;
                let p = predictor::predict(&cfg).unwrap();
                if let Some(q) = prev {
                    let ctx = format!("{model} tp {tp} ({base:?})");
                    assert!(p.param_mib <= q.param_mib + 1e-3, "param grew: {ctx}");
                    assert!(p.grad_mib <= q.grad_mib + 1e-3, "grad grew: {ctx}");
                    assert!(p.opt_mib <= q.opt_mib + 1e-3, "opt grew: {ctx}");
                    assert!(p.peak_mib <= q.peak_mib + 1e-3, "peak grew: {ctx}");
                }
                prev = Some(p);
            }
        }
    }
}

/// Invariant 1 on the ground-truth side: the simulator's per-rank peak
/// is non-increasing in tp too (allocator rounding gets a small slack).
#[test]
fn tp_simulated_peak_non_increasing() {
    let mut rng = Prng::new(0xB0B);
    for model in all_models() {
        let base = random_cfg(&mut rng, &model);
        let peaks: Vec<f64> = [1u64, 2, 4]
            .iter()
            .map(|&tp| {
                let mut cfg = base.clone();
                cfg.tp = tp;
                simulator::simulate(&cfg).unwrap().peak_mib
            })
            .collect();
        for w in peaks.windows(2) {
            // small slack: the caching allocator's segment rounding is
            // not perfectly monotone in request sizes
            assert!(w[1] <= w[0] + 8.0, "{model}: {peaks:?}");
        }
    }
}

/// Invariant 2: max-over-stages per-rank peak <= single-device peak.
/// The harmonic act-balanced partition guarantees this up to
/// block-granularity discretization, hence the small tolerance.
#[test]
fn pp_max_stage_peak_le_single_device() {
    let mut rng = Prng::new(0xC0FFEE);
    for model in all_models() {
        for _case in 0..2 {
            let base = random_cfg(&mut rng, &model);
            let single_pred = predictor::predict(&base).unwrap().peak_mib as f64;
            let single_sim = simulator::simulate(&base).unwrap().peak_mib;
            for pp in [2u64, 4] {
                let mut cfg = base.clone();
                cfg.pp = pp;
                let rp = predictor::predict_per_rank(&cfg).unwrap();
                assert_eq!(rp.per_stage.len(), pp as usize);
                let rank_pred = rp.peak_mib() as f64;
                assert!(
                    rank_pred <= single_pred * 1.02 + 16.0,
                    "{model} pp {pp}: predicted per-rank {rank_pred} vs single {single_pred}"
                );
                let rank_sim = simulator::simulate(&cfg).unwrap().peak_mib;
                assert!(
                    rank_sim <= single_sim * 1.02 + 16.0,
                    "{model} pp {pp}: simulated per-rank {rank_sim} vs single {single_sim}"
                );
            }
        }
    }
}

/// Invariant 2b: the stage views tile the layer list and partition the
/// (tp-sharded) weights exactly — no layer counted twice or dropped.
#[test]
fn pp_stage_views_partition_weights_exactly() {
    let mut rng = Prng::new(0xD1CE);
    for model in all_models() {
        let mut cfg = random_cfg(&mut rng, &model);
        cfg.tp = *rng.pick(&[1u64, 2]);
        let pm = parser::parse(&cfg).unwrap();
        for pp in [2u64, 3, 4] {
            let bounds = pipeline::stage_bounds(&pm, pp).unwrap();
            assert_eq!(bounds[0].0, 0);
            assert_eq!(bounds.last().unwrap().1, pm.layers.len());
            let mut total = 0u64;
            let mut trainable = 0u64;
            for (s, &b) in bounds.iter().enumerate() {
                let view = pipeline::stage_view(&pm, b, pipeline::in_flight(pp, s));
                total += view.total_param_elems;
                trainable += view.trainable_param_elems;
            }
            assert_eq!(total, pm.total_param_elems, "{model} pp {pp}");
            assert_eq!(trainable, pm.trainable_param_elems, "{model} pp {pp}");
        }
    }
}

/// Invariant 3: ZeRO-3 + dp divides the optimizer state exactly. The
/// simulator's flat buffers hold `ceil(T/dp)` elements per state; the
/// predictor's optimizer term scales bitwise by `1/dp` for
/// power-of-two dp (multiplication by 2^-k commutes with f32
/// rounding).
#[test]
fn zero3_dp_sharding_divides_optimizer_exactly() {
    let mut rng = Prng::new(0xFEED);
    for model in all_models() {
        let mut base = random_cfg(&mut rng, &model);
        base.stage = Stage::Finetune;
        base.zero = ZeroStage::Zero3;
        base.dp = 1;
        let pm = parser::parse(&base).unwrap();
        let t = pm.trainable_param_elems;
        if t == 0 {
            continue; // unimodal pretrain-style configs have no states
        }
        let opt1 = predictor::predict(&base).unwrap().opt_mib;
        for dp in [2u64, 4, 8] {
            let mut cfg = base.clone();
            cfg.dp = dp;
            // flat buffers: ceil(T/dp) elements per state, 4 bytes each
            let bufs = zero::buffers(&pm, &cfg);
            for &state in &bufs.opt_state_bytes {
                assert_eq!(state, t.div_ceil(dp) * 4, "{model} dp {dp}");
            }
            assert_eq!(bufs.master_bytes % 4, 0);
            // the shards cover T exactly (last rank padded < dp elems)
            assert!(dp * t.div_ceil(dp) >= t);
            assert!(dp * t.div_ceil(dp) < t + dp);
            // predictor term divides bitwise for power-of-two dp
            let optd = predictor::predict(&cfg).unwrap().opt_mib;
            assert!(
                (optd * dp as f32 - opt1).abs() <= opt1 * 1e-6,
                "{model} dp {dp}: {optd} * {dp} != {opt1}"
            );
        }
    }
}

/// Invariant 4: tp = pp = dp = 1 degenerates to the single-device code
/// path bitwise — the per-rank APIs return exactly what the plain
/// `predict`/`simulate` calls return (whose absolute values the golden
/// parity suite in tests/parity.rs pins against the legacy fixtures).
#[test]
fn trivial_parallelism_is_bitwise_single_device() {
    for model in all_models() {
        let cfg = TrainConfig {
            model: model.clone(),
            mbs: 1,
            seq_len: 64,
            ..TrainConfig::llava_finetune_default()
        };
        let p = predictor::predict(&cfg).unwrap();
        let rp = predictor::predict_per_rank(&cfg).unwrap();
        assert_eq!(rp.per_stage.len(), 1, "{model}");
        assert_eq!(rp.binding_stage, 0, "{model}");
        assert_eq!(*rp.binding(), p, "{model}");

        let m = simulator::simulate(&cfg).unwrap();
        let per = simulator::simulate_per_rank(&cfg).unwrap();
        assert_eq!(per.len(), 1, "{model}");
        assert_eq!(per[0].peak_mib, m.peak_mib, "{model}");
        assert_eq!(per[0].pp_stage, 0, "{model}");
        assert_eq!(m.pp_stage, 0, "{model}");
    }
}

/// tp composes with ZeRO: the bucket and step transients size off the
/// tp-sharded trainable footprint, so they shrink monotonically too.
#[test]
fn tp_shrinks_zero_buffers() {
    let mut rng = Prng::new(0x5EED);
    for model in all_models() {
        let mut cfg = random_cfg(&mut rng, &model);
        cfg.stage = Stage::Finetune;
        cfg.zero = ZeroStage::Zero2;
        let pm1 = parser::parse(&cfg).unwrap();
        if pm1.trainable_param_elems == 0 {
            continue;
        }
        let b1 = zero::buffers(&pm1, &cfg);
        let mut cfg2 = cfg.clone();
        cfg2.tp = 4;
        let pm2 = parser::parse(&cfg2).unwrap();
        let b2 = zero::buffers(&pm2, &cfg2);
        assert!(pm2.trainable_param_elems < pm1.trainable_param_elems, "{model}");
        assert!(b2.master_bytes <= b1.master_bytes, "{model}");
        assert!(b2.step_temp_bytes <= b1.step_temp_bytes, "{model}");
        assert!(b2.bucket_capacity <= b1.bucket_capacity, "{model}");
    }
}
