//! Prediction-service integration: concurrent clients, batching
//! behaviour, metrics and error paths. The tensorized tests skip
//! without artifacts; the analytical tests always run.

use std::time::Duration;

use mmpredict::api::{ApiRequest, Method, PredictParams};
use mmpredict::config::TrainConfig;
use mmpredict::coordinator::batcher::BatchPolicy;
use mmpredict::coordinator::{PredictionService, ServiceConfig};

fn service() -> Option<PredictionService> {
    let dir = mmpredict::runtime::default_artifacts_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("SKIP: no artifacts at {dir}/ — run `make artifacts`");
        return None;
    }
    Some(
        PredictionService::start(
            &dir,
            ServiceConfig {
                policy: BatchPolicy {
                    max_batch: 8,
                    batch_timeout: Duration::from_millis(3),
                },
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

/// Always-on (analytical) coverage: concurrent wire-envelope submits
/// batch, answer correctly, and advance the global + per-method
/// counters.
#[test]
fn analytical_service_batches_envelopes_and_counts_methods() {
    let svc = PredictionService::start_analytical(ServiceConfig {
        policy: BatchPolicy {
            max_batch: 8,
            batch_timeout: Duration::from_millis(3),
        },
        ..Default::default()
    });
    let tiny = TrainConfig {
        model: "llava-tiny".into(),
        mbs: 1,
        seq_len: 32,
        ..TrainConfig::llava_finetune_default()
    };
    let expected = mmpredict::predictor::predict(&tiny).unwrap();

    let handles: Vec<_> = (0..16u64)
        .map(|i| {
            let client = svc.client();
            let cfg = tiny.clone();
            std::thread::spawn(move || {
                let resp = client.submit(ApiRequest::new(
                    format!("r{i}"),
                    Method::Predict(PredictParams { cfg, capacity_mib: None, detail: false }),
                ));
                assert_eq!(resp.id.as_deref(), Some(format!("r{i}").as_str()));
                resp.result.unwrap()
            })
        })
        .collect();
    for h in handles {
        let payload = h.join().unwrap();
        let p = mmpredict::api::codec::prediction_from_json(
            payload.get("prediction").unwrap(),
        )
        .unwrap();
        assert_eq!(p, expected);
    }
    let m = svc.metrics();
    assert_eq!(m.requests(), 16);
    assert_eq!(m.responses(), 16);
    assert_eq!(m.errors(), 0);
    assert_eq!(m.method_requests(0), 16, "predict method counter");
    assert!(m.batches() < 16, "batching should have happened: {}", m.summary());
    let (p50, p95, p99, max) = m.method_latency_us(0);
    assert!(
        p50 > 0 && p95 >= p50 && p99 >= p95 && max >= p99 / 2,
        "{p50}/{p95}/{p99}/{max}"
    );
    // 16 identical configs: the first is a cold miss, repeats may hit
    // the geometry-keyed payload cache — but hits + cold answers must
    // account for every request with no error either way.
    let (hits, misses) = m.response_cache();
    assert_eq!(hits + misses, 16, "every predict consults the cache");
    assert!(misses >= 1, "first arrival can never hit");
    svc.shutdown();
}

#[test]
fn concurrent_clients_get_correct_answers() {
    let Some(svc) = service() else { return };
    let expected: Vec<f32> = (1..=8)
        .map(|dp| {
            mmpredict::predictor::predict(&TrainConfig::fig2b(dp)).unwrap().peak_mib
        })
        .collect();

    let mut handles = Vec::new();
    for round in 0..4 {
        for dp in 1..=8u64 {
            let client = svc.client();
            handles.push(std::thread::spawn(move || {
                let p = client.predict(TrainConfig::fig2b(dp)).unwrap();
                (round, dp, p.peak_mib)
            }));
        }
    }
    for h in handles {
        let (_, dp, peak) = h.join().unwrap();
        let want = expected[(dp - 1) as usize];
        assert!(
            (peak - want).abs() / want < 1e-4,
            "dp{dp}: {peak} vs {want}"
        );
    }
    assert_eq!(svc.metrics().responses(), 32);
    assert_eq!(svc.metrics().errors(), 0);
    // batching must have happened (fewer batches than requests)
    assert!(svc.metrics().batches() < 32, "{}", svc.metrics().summary());
    svc.shutdown();
}

#[test]
fn shutdown_drains_inflight_jobs() {
    let Some(svc) = service() else { return };
    // Clients queue jobs, then the service shuts down while they are in
    // flight. Every submitted job must still receive a real answer —
    // the worker drains the queue before exiting rather than dropping
    // buffered jobs on the floor.
    let handles: Vec<_> = (0..8u64)
        .map(|i| {
            let client = svc.client();
            std::thread::spawn(move || client.predict(TrainConfig::fig2b(i % 8 + 1)))
        })
        .collect();
    // shutdown joins the worker; the worker keeps serving until the last
    // client sender is gone, so this cannot complete with jobs stranded
    svc.shutdown();
    for h in handles {
        let p = h.join().unwrap().expect("job dropped during shutdown");
        assert!(p.peak_mib > 0.0);
    }
}

#[test]
fn invalid_configs_get_errors_not_hangs() {
    let Some(svc) = service() else { return };
    let mut bad = TrainConfig::fig2b(1);
    bad.model = "not-a-model".into();
    let err = svc.predict(bad);
    assert!(err.is_err());
    assert_eq!(svc.metrics().errors(), 1);
    // the service still works afterwards
    let ok = svc.predict(TrainConfig::fig2b(2)).unwrap();
    assert!(ok.peak_mib > 0.0);
    svc.shutdown();
}

#[test]
fn mixed_model_batches() {
    let Some(svc) = service() else { return };
    let mut handles = Vec::new();
    for model in ["llava-1.5-7b", "llava-1.5-13b", "llava-tiny"] {
        for dp in [1u64, 8] {
            let client = svc.client();
            let cfg = TrainConfig {
                model: model.to_string(),
                ..TrainConfig::fig2b(dp)
            };
            handles.push(std::thread::spawn(move || (model, dp, client.predict(cfg).unwrap())));
        }
    }
    let mut peaks = std::collections::HashMap::new();
    for h in handles {
        let (model, dp, p) = h.join().unwrap();
        peaks.insert((model, dp), p.peak_mib);
    }
    // 13B > 7B > tiny at the same dp
    assert!(peaks[&("llava-1.5-13b", 1)] > peaks[&("llava-1.5-7b", 1)]);
    assert!(peaks[&("llava-1.5-7b", 1)] > peaks[&("llava-tiny", 1)]);
    svc.shutdown();
}
