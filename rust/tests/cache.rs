//! Cache-correctness acceptance (PR 8): a geometry-keyed cache hit is
//! only legal if it is **bitwise identical** to the cold answer it
//! replaced. Every cached method (predict / simulate / baselines /
//! modality / frag) is exercised twice per config — across tp/pp parallel
//! geometries and file-based architecture specs — and the repeated
//! payload must serialize to the very same bytes, with the service
//! metrics proving the second answer really was a hit. A zero-cap
//! service must behave identically while never consulting the cache.

use std::time::Duration;

use mmpredict::api::{
    self, ApiRequest, ApiResponse, BaselinesParams, FragParams, Method, ModalityParams,
    PredictParams, SimulateParams,
};
use mmpredict::config::TrainConfig;
use mmpredict::coordinator::batcher::BatchPolicy;
use mmpredict::coordinator::{PredictionService, ServiceConfig};

fn tiny() -> TrainConfig {
    TrainConfig {
        model: "llava-tiny".into(),
        mbs: 1,
        seq_len: 32,
        ..TrainConfig::llava_finetune_default()
    }
}

fn arch_cfg(name: &str) -> TrainConfig {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/archs")
        .join(name);
    TrainConfig {
        model: path.to_str().unwrap().to_string(),
        seq_len: 4096,
        mbs: 2,
        dp: 2,
        ..TrainConfig::llava_finetune_default()
    }
}

fn start(cache_cap: usize) -> PredictionService {
    PredictionService::start_analytical(ServiceConfig {
        policy: BatchPolicy { max_batch: 8, batch_timeout: Duration::ZERO },
        cache_cap,
        ..Default::default()
    })
}

/// One request per cached method for `cfg`.
fn cached_method_requests(cfg: &TrainConfig, tag: &str) -> Vec<ApiRequest> {
    vec![
        ApiRequest::new(
            format!("{tag}-predict"),
            Method::Predict(PredictParams {
                cfg: cfg.clone(),
                capacity_mib: None,
                detail: false,
            }),
        ),
        ApiRequest::new(
            format!("{tag}-simulate"),
            Method::Simulate(SimulateParams { cfg: cfg.clone() }),
        ),
        ApiRequest::new(
            format!("{tag}-baselines"),
            Method::Baselines(BaselinesParams { cfg: cfg.clone() }),
        ),
        ApiRequest::new(
            format!("{tag}-modality"),
            Method::Modality(ModalityParams { cfg: cfg.clone() }),
        ),
        ApiRequest::new(
            format!("{tag}-frag"),
            Method::Frag(FragParams { cfg: cfg.clone(), top_k: 3 }),
        ),
    ]
}

fn ok_bytes(resp: ApiResponse, what: &str) -> String {
    resp.result
        .unwrap_or_else(|e| panic!("{what}: {e}"))
        .to_string()
}

/// The acceptance matrix: every cached method, over single-GPU,
/// tensor-parallel, pipeline-parallel and file-spec geometries. The
/// repeat of each request must come back byte-identical, and the
/// metrics must show one hit per repeat.
#[test]
fn cache_hits_are_bitwise_identical_across_methods_and_geometries() {
    let svc = start(256);
    let configs: Vec<(&str, TrainConfig)> = vec![
        ("tiny", tiny()),
        ("tp2", TrainConfig { tp: 2, ..tiny() }),
        ("pp2", TrainConfig { pp: 2, ..tiny() }),
        ("arch", arch_cfg("llava-interleave.toml")),
    ];
    let mut pairs = 0u64;
    for (tag, cfg) in &configs {
        for req in cached_method_requests(cfg, tag) {
            let what = format!("{tag}/{}", req.method.name());
            let cold = ok_bytes(svc.submit(req.clone()), &what);
            let hit = ok_bytes(svc.submit(req.clone()), &what);
            assert_eq!(cold, hit, "{what}: cached repeat diverged from the cold answer");
            // a third probe: hits must be stable, not one-shot
            let again = ok_bytes(svc.submit(req), &what);
            assert_eq!(cold, again, "{what}: third answer diverged");
            pairs += 1;
        }
    }
    let (hits, misses) = svc.metrics().response_cache();
    assert_eq!(misses, pairs, "exactly one cold miss per (config, method)");
    assert_eq!(hits, 2 * pairs, "both repeats of every pair must hit");
    svc.shutdown();
}

/// `--cache-cap 0` disables caching without changing a single byte of
/// any answer: the repeated responses still agree (the pipeline is
/// deterministic), but the metrics show the cache was never consulted.
#[test]
fn zero_cap_disables_caching_but_not_determinism() {
    let svc = start(0);
    for req in cached_method_requests(&tiny(), "z") {
        let what = format!("zero-cap/{}", req.method.name());
        let first = ok_bytes(svc.submit(req.clone()), &what);
        let second = ok_bytes(svc.submit(req), &what);
        assert_eq!(first, second, "{what}: cold path must stay deterministic");
    }
    let m = svc.metrics();
    assert_eq!(m.response_cache(), (0, 0), "cap 0 never consults the payload cache");
    assert_eq!(m.parse_cache(), (0, 0), "cap 0 never consults the parse cache");
    assert_eq!(m.sim_cache(), (0, 0), "cap 0 never consults the replay cache");
    svc.shutdown();
}

/// Cached answers agree with a fresh, cache-free service: the cache can
/// only ever replay what the cold pipeline would have produced.
#[test]
fn cached_service_agrees_with_uncached_service() {
    let cached = start(256);
    let uncached = start(0);
    for (tag, cfg) in [
        ("a", tiny()),
        ("b", TrainConfig { seq_len: 64, ..tiny() }),
        ("arch", arch_cfg("audio-lang.toml")),
    ] {
        for req in cached_method_requests(&cfg, tag) {
            let what = format!("{tag}/{}", req.method.name());
            // warm the cached service, then compare its *hit* against
            // the uncached service's cold answer
            let _ = ok_bytes(cached.submit(req.clone()), &what);
            let hit = ok_bytes(cached.submit(req.clone()), &what);
            let cold = ok_bytes(uncached.submit(req), &what);
            assert_eq!(hit, cold, "{what}: hit diverged from a cache-free service");
        }
    }
    let (hits, _) = cached.metrics().response_cache();
    assert!(hits > 0, "the cached service must actually have served hits");
    assert_eq!(uncached.metrics().response_cache(), (0, 0));
    cached.shutdown();
    uncached.shutdown();
}

/// Simulate answers flow through the incremental columnar replay on
/// repeat geometries (dp/zero variations share one skeleton); the wire
/// answer must not depend on whether the checkpointed replay or the
/// scalar oracle produced it. api::SweepParams-style dp fans share the
/// geometry, so the second config exercises the divergent-suffix path.
#[test]
fn incremental_simulate_matches_scalar_across_shard_variants() {
    let svc = start(256);
    let base = tiny();
    let scalar = start(0);
    for dp in [1u64, 2, 4] {
        for zero in [
            mmpredict::config::ZeroStage::Zero0,
            mmpredict::config::ZeroStage::Zero2,
        ] {
            let cfg = TrainConfig { dp, zero, ..base.clone() };
            let req = ApiRequest::new(
                format!("s-dp{dp}-{zero:?}"),
                Method::Simulate(api::SimulateParams { cfg }),
            );
            let what = format!("simulate dp{dp}/{zero:?}");
            let inc = ok_bytes(svc.submit(req.clone()), &what);
            let cold = ok_bytes(scalar.submit(req), &what);
            assert_eq!(inc, cold, "{what}: incremental replay diverged from scalar");
        }
    }
    svc.shutdown();
    scalar.shutdown();
}
