//! Golden parity suite: every pre-existing zoo preset, lowered through
//! the declarative architecture IR, must match the pre-refactor
//! hand-built composition exactly.
//!
//! The fixtures below reproduce the deleted `zoo::llava()` /
//! `zoo::unimodal()` builders verbatim (captured before the old code
//! paths were removed): a LLaVA preset was `vision::build(&vit)` +
//! `projector::mlp2x_gelu(vit.hidden, lm.hidden)` +
//! `language::build(&lm, seq_len)` with single-image 577/576 token
//! geometry; a unimodal preset was the bare decoder. The suite pins
//!
//! * the exact layer sequence (names, kinds, modalities),
//! * `param_elems()` totals, and
//! * bit-identical analytical predictions
//!
//! between the IR path (`zoo::build` → `ArchSpec::lower`) and the
//! legacy composition.

use mmpredict::config::TrainConfig;
use mmpredict::model::dims::{Modality, TokenCtx, TokenStream};
use mmpredict::model::language::{self, LlamaConfig};
use mmpredict::model::layer::AttnImpl;
use mmpredict::model::module::ModelSpec;
use mmpredict::model::vision::{self, VitConfig};
use mmpredict::model::{lora, projector, zoo};
use mmpredict::parser::{self, features};
use mmpredict::predictor::{analytical, Prediction};

/// The pre-refactor LLaVA composition (legacy `zoo::llava`).
fn legacy_llava(
    name: &str,
    vit: VitConfig,
    lm: LlamaConfig,
    seq_len: u64,
) -> (ModelSpec, u64, u64) {
    let mut spec = ModelSpec::new(name);
    spec.modules.push(vision::build(&vit));
    spec.modules.push(projector::mlp2x_gelu(vit.hidden, lm.hidden));
    spec.modules.push(language::build(&lm, seq_len));
    (spec, vit.seq_tokens(), vit.patch_tokens())
}

/// The pre-refactor unimodal composition (legacy `zoo::unimodal`).
fn legacy_unimodal(name: &str, lm: LlamaConfig, seq_len: u64) -> (ModelSpec, u64, u64) {
    let mut spec = ModelSpec::new(name);
    spec.modules.push(language::build(&lm, seq_len));
    (spec, 0, 0)
}

/// The pre-refactor `ZooEntry::token_ctx`: single vision + projector
/// streams with the LLaVA 577/576 geometry (or none when unimodal),
/// `images_per_sample` forced to 0 for unimodal models.
fn legacy_token_ctx(
    mbs: u64,
    seq_len: u64,
    vision_tokens: u64,
    image_tokens: u64,
    images_per_sample: u64,
) -> TokenCtx {
    let mut streams = Vec::new();
    if vision_tokens > 0 {
        streams.push(TokenStream {
            module: "vision_tower".into(),
            modality: Modality::Vision,
            tokens_per_item: vision_tokens,
            items_per_sample: images_per_sample,
        });
        streams.push(TokenStream {
            module: "mm_projector".into(),
            modality: Modality::Projector,
            tokens_per_item: image_tokens,
            items_per_sample: images_per_sample,
        });
    }
    TokenCtx { mbs, seq_len, streams }
}

/// Build the legacy composition for a preset name exactly as the
/// pre-refactor `zoo::build` match arms did (including which presets
/// honoured the `attn` argument).
fn legacy_build(name: &str, seq_len: u64, attn: AttnImpl) -> (ModelSpec, u64, u64) {
    match name {
        "llava-1.5-7b" => {
            legacy_llava(name, vision::clip_vit_l14_336(), language::vicuna_7b(attn), seq_len)
        }
        "llava-1.5-13b" => {
            legacy_llava(name, vision::clip_vit_l14_336(), language::vicuna_13b(attn), seq_len)
        }
        "llava-tiny" => legacy_llava(name, vision::vit_tiny(), language::llama_tiny(), seq_len),
        "vicuna-7b" => legacy_unimodal(name, language::vicuna_7b(attn), seq_len),
        "vicuna-13b" => legacy_unimodal(name, language::vicuna_13b(attn), seq_len),
        "llama-tiny" => legacy_unimodal(name, language::llama_tiny(), seq_len),
        other => panic!("no legacy fixture for {other}"),
    }
}

const LEGACY_NAMES: [&str; 6] = [
    "llava-1.5-7b",
    "llava-1.5-13b",
    "llava-tiny",
    "vicuna-7b",
    "vicuna-13b",
    "llama-tiny",
];

#[test]
fn registry_still_contains_every_legacy_name() {
    let names = zoo::names();
    for n in LEGACY_NAMES {
        assert!(names.contains(&n), "preset {n} disappeared from the registry");
    }
}

#[test]
fn ir_lowering_matches_legacy_layer_sequences() {
    for name in LEGACY_NAMES {
        for attn in [AttnImpl::Flash, AttnImpl::Eager] {
            let seq_len = 512;
            let ir = zoo::build(name, seq_len, attn).unwrap();
            let (legacy, vision_tokens, image_tokens) = legacy_build(name, seq_len, attn);

            assert_eq!(
                ir.spec.num_layers(),
                legacy.num_layers(),
                "{name}/{attn:?}: layer count"
            );
            assert_eq!(ir.spec.name, legacy.name, "{name}: model name");
            assert_eq!(
                ir.spec.modules.len(),
                legacy.modules.len(),
                "{name}: module count"
            );
            for (a, b) in ir.spec.layers().zip(legacy.layers()) {
                assert_eq!(a.name, b.name, "{name}/{attn:?}: layer name");
                assert_eq!(a.kind, b.kind, "{name}/{attn:?}: kind of {}", a.name);
                assert_eq!(a.modality, b.modality, "{name}/{attn:?}: modality of {}", a.name);
            }
            assert_eq!(
                ir.spec.param_elems(),
                legacy.param_elems(),
                "{name}/{attn:?}: param_elems"
            );
            assert_eq!(ir.vision_tokens(), vision_tokens, "{name}: vision tokens");
            assert_eq!(ir.image_tokens(), image_tokens, "{name}: image tokens");
        }
    }
}

/// Predict through the legacy composition: fixture spec + fixture
/// token geometry through the same parse/encode/factorize pipeline.
fn legacy_predict(cfg: &TrainConfig) -> Prediction {
    let (mut spec, vision_tokens, image_tokens) = legacy_build(&cfg.model, cfg.seq_len, cfg.attn);
    if let Some(lc) = &cfg.lora {
        lora::apply(&mut spec, lc);
    }
    let images = if vision_tokens == 0 { 0 } else { cfg.images_per_sample };
    let ctx = legacy_token_ctx(cfg.mbs, cfg.seq_len, vision_tokens, image_tokens, images);
    let pm = parser::parse_spec(&spec, ctx, cfg);
    analytical::predict_encoded(&features::encode(&pm, cfg))
}

#[test]
fn ir_predictions_are_bit_identical_to_legacy() {
    for name in LEGACY_NAMES {
        for (mbs, seq_len, dp) in [(16, 1024, 1), (8, 2048, 4)] {
            let cfg = TrainConfig {
                model: name.to_string(),
                mbs,
                seq_len,
                dp,
                ..TrainConfig::llava_finetune_default()
            };
            let ir = mmpredict::predictor::predict(&cfg).unwrap();
            let legacy = legacy_predict(&cfg);
            assert_eq!(ir, legacy, "{name} mbs={mbs} seq={seq_len} dp={dp}");
        }
    }
}

#[test]
fn ir_predictions_match_legacy_across_stages_and_attention() {
    use mmpredict::config::Stage;
    for stage in [Stage::Pretrain, Stage::Finetune, Stage::Full] {
        for attn in [AttnImpl::Flash, AttnImpl::Eager] {
            let cfg = TrainConfig {
                model: "llava-tiny".into(),
                stage,
                mbs: 4,
                seq_len: 256,
                attn,
                ..TrainConfig::llava_finetune_default()
            };
            let ir = mmpredict::predictor::predict(&cfg).unwrap();
            assert_eq!(ir, legacy_predict(&cfg), "stage={stage:?} attn={attn:?}");
        }
    }
}

#[test]
fn ir_predictions_match_legacy_under_lora() {
    let cfg = TrainConfig {
        model: "llava-1.5-7b".into(),
        stage: mmpredict::config::Stage::LoraFinetune,
        lora: Some(mmpredict::model::lora::LoraConfig { rank: 16, ..Default::default() }),
        mbs: 8,
        seq_len: 1024,
        dp: 2,
        ..TrainConfig::llava_finetune_default()
    };
    let ir = mmpredict::predictor::predict(&cfg).unwrap();
    assert_eq!(ir, legacy_predict(&cfg));
}

#[test]
fn ir_simulator_measurements_match_legacy_parse() {
    // The simulator consumes the same LayerRecords; a legacy-parsed
    // model must replay to the identical measurement.
    let cfg = TrainConfig {
        model: "llava-tiny".into(),
        mbs: 2,
        seq_len: 128,
        ..TrainConfig::llava_finetune_default()
    };
    let ir = mmpredict::simulator::simulate(&cfg).unwrap();

    let (spec, vt, it) = legacy_build(&cfg.model, cfg.seq_len, cfg.attn);
    let ctx = legacy_token_ctx(cfg.mbs, cfg.seq_len, vt, it, cfg.images_per_sample);
    let pm = parser::parse_spec(&spec, ctx, &cfg);
    let mut sim_ctx = mmpredict::simulator::SimContext::new();
    let legacy = mmpredict::simulator::simulate_parsed(&pm, &cfg, &mut sim_ctx).unwrap();

    assert_eq!(ir.peak_mib, legacy.peak_mib);
    assert_eq!(ir.at_peak, legacy.at_peak);
    assert_eq!(ir.alloc_count, legacy.alloc_count);
}
