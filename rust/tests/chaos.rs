//! Chaos acceptance: seeded fault schedules driven through the whole
//! service stack over loopback TCP. The invariants under test:
//!
//! * every accepted request gets **exactly one** well-formed v1
//!   response or a clean disconnect — never a hang, never a torn
//!   protocol state that poisons the next request;
//! * a panicking worker is isolated (`catch_unwind`), answered with a
//!   structured `internal`, and respawned — the service stays up;
//! * deadlines produce structured `deadline_exceeded`, and tight (but
//!   live) deadlines degrade `plan`/`sweep` to analytical-only answers
//!   explicitly marked `degraded: true` — never silently wrong;
//! * backpressure (`over_capacity`) carries a `retry_after_ms` hint;
//! * shutdown drains in-flight work and is not pinned by a client that
//!   stops reading its socket (write-timeout path);
//! * with no fault plan, none of the robustness machinery leaks into
//!   responses.
//!
//! Every test derives its schedule from `REPRO_CHAOS_SEED` (default
//! pinned) and logs it, so a CI failure replays exactly.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mmpredict::api::fault::{FaultPlan, FaultState, Site};
use mmpredict::api::serve::ServeOptions;
use mmpredict::api::{self, ApiRequest, ApiResponse, ErrorCode, Method, PredictParams};
use mmpredict::config::TrainConfig;
use mmpredict::coordinator::{PredictionService, ServiceConfig};
use mmpredict::planner::{Axes, PlanRequest};
use mmpredict::util::json_mini::Json;

/// The schedule seed: `REPRO_CHAOS_SEED` when set (CI's randomized
/// job), else pinned. Always logged so failures replay.
fn chaos_seed() -> u64 {
    let seed = std::env::var("REPRO_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    eprintln!("chaos seed: {seed}");
    seed
}

fn tiny() -> TrainConfig {
    TrainConfig {
        model: "llava-tiny".into(),
        mbs: 1,
        seq_len: 32,
        ..TrainConfig::llava_finetune_default()
    }
}

fn service_with(plan: FaultPlan) -> (PredictionService, Arc<FaultState>) {
    let faults = Arc::new(FaultState::new(plan));
    let svc = PredictionService::start_analytical(ServiceConfig {
        faults: faults.clone(),
        ..Default::default()
    });
    (svc, faults)
}

fn predict_line(id: &str) -> String {
    ApiRequest::new(
        id,
        Method::Predict(PredictParams { cfg: tiny(), capacity_mib: None, detail: false }),
    )
    .to_json()
    .to_string()
}

fn plan_request(deadline_ms: Option<u64>) -> ApiRequest {
    let base = tiny();
    let req = ApiRequest::new(
        "plan",
        Method::Plan(api::PlanParams {
            req: PlanRequest {
                base: base.clone(),
                budget_mib: 1e9,
                axes: Axes { mbs: vec![1, 2], ..Axes::fixed(&base) },
            },
        }),
    );
    match deadline_ms {
        Some(ms) => req.with_deadline_ms(ms),
        None => req,
    }
}

fn sweep_request(deadline_ms: Option<u64>) -> ApiRequest {
    let base = tiny();
    let req = ApiRequest::new(
        "sweep",
        Method::Sweep(api::SweepParams {
            zero: vec![base.zero],
            base,
            dp: vec![1, 2],
            mbs: vec![1],
            seq_len: vec![32],
            capacity_mib: None,
        }),
    );
    match deadline_ms {
        Some(ms) => req.with_deadline_ms(ms),
        None => req,
    }
}

/// One exchange outcome as a chaos client sees it.
enum Outcome {
    Response(ApiResponse),
    Disconnect,
}

/// Minimal reconnecting NDJSON client. A read that produces no newline
/// (torn frame) or an EOF is a *clean disconnect*; a read timeout is a
/// server hang and fails the test.
struct RawClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl RawClient {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        RawClient {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn call(&mut self, line: &str) -> Outcome {
        if writeln!(self.writer, "{line}").is_err() || self.writer.flush().is_err() {
            return Outcome::Disconnect;
        }
        let mut buf = String::new();
        match self.reader.read_line(&mut buf) {
            Ok(0) => Outcome::Disconnect,
            Ok(_) if !buf.ends_with('\n') => Outcome::Disconnect, // torn frame
            Ok(_) => Outcome::Response(
                ApiResponse::parse_line(buf.trim()).expect("well-formed v1 response"),
            ),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("server hung: no response within 10s")
            }
            Err(_) => Outcome::Disconnect,
        }
    }
}

/// The acceptance storm: every failpoint armed at a moderate rate,
/// concurrent clients mixing methods. Each request retries across
/// disconnects until it gets exactly one well-formed response; the
/// server must never hang and must shut down cleanly afterwards.
#[test]
fn seeded_fault_storm_never_hangs_and_always_answers_or_disconnects() {
    let plan = FaultPlan {
        seed: chaos_seed(),
        accept_drop: 0.10,
        accept_stall: 0.20,
        accept_stall_ms: 2,
        read_stall: 0.20,
        read_stall_ms: 2,
        write_stall: 0.20,
        write_stall_ms: 2,
        partial_frame: 0.10,
        conn_drop: 0.15,
        latency: 0.30,
        latency_ms: 3,
        internal: 0.10,
        backend_unavailable: 0.05,
        worker_panic: 0.10,
        queue_reject: 0.10,
    };
    let (svc, faults) = service_with(plan);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = api::serve::serve(
        listener,
        svc,
        &ServeOptions { conn_threads: 4, ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();

    const CLIENTS: usize = 4;
    const REQS: usize = 25;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|t| {
            std::thread::spawn(move || {
                let mut client = RawClient::connect(addr);
                let mut disconnects = 0usize;
                let mut predict_payloads: Vec<String> = Vec::new();
                for i in 0..REQS {
                    let id = format!("t{t}-r{i}");
                    let line = match i % 3 {
                        0 => predict_line(&id),
                        1 => format!(r#"{{"v":1,"id":"{id}","method":"models"}}"#),
                        _ => format!(r#"{{"v":1,"id":"{id}","method":"health"}}"#),
                    };
                    // retry across disconnects until this request gets
                    // its one well-formed response
                    let mut attempts = 0;
                    loop {
                        match client.call(&line) {
                            Outcome::Response(resp) => {
                                assert_eq!(
                                    resp.id.as_deref(),
                                    Some(id.as_str()),
                                    "response/request id correlation"
                                );
                                // errors are fine (injected), but they
                                // must be structured ones
                                match &resp.result {
                                    Err(e) => {
                                        assert!(
                                            matches!(
                                                e.code,
                                                ErrorCode::Internal
                                                    | ErrorCode::BackendUnavailable
                                                    | ErrorCode::OverCapacity
                                            ),
                                            "unexpected error under chaos: {e}"
                                        );
                                    }
                                    // cache-consistency under chaos: the
                                    // predict config is pinned, so every
                                    // successful payload — cold, cached,
                                    // or recomputed after a mid-storm
                                    // respawn — must be byte-identical
                                    Ok(payload) if i % 3 == 0 => {
                                        predict_payloads.push(payload.to_string());
                                    }
                                    Ok(_) => {}
                                }
                                break;
                            }
                            Outcome::Disconnect => {
                                disconnects += 1;
                                attempts += 1;
                                assert!(
                                    attempts < 50,
                                    "request {id} could not complete after 50 reconnects"
                                );
                                client = RawClient::connect(addr);
                            }
                        }
                    }
                }
                (disconnects, predict_payloads)
            })
        })
        .collect();
    let mut disconnects = 0usize;
    let mut payloads: Vec<String> = Vec::new();
    for h in handles {
        let (d, p) = h.join().expect("client");
        disconnects += d;
        payloads.extend(p);
    }
    eprintln!(
        "storm: {} responses, {} clean disconnects, {} faults injected",
        CLIENTS * REQS,
        disconnects,
        faults.injected()
    );
    assert!(faults.injected() > 0, "storm plan injected nothing");
    assert!(!payloads.is_empty(), "the storm produced no successful predicts");
    payloads.sort();
    payloads.dedup();
    assert_eq!(
        payloads.len(),
        1,
        "a cached predict served stale or torn bytes under the storm"
    );
    server.shutdown(); // must return (drain bounded)
}

/// Injected worker panics are isolated per job: structured `internal`
/// replies, worker respawn counted, service alive throughout.
#[test]
fn worker_panics_are_isolated_and_respawned() {
    let (svc, _faults) = service_with(FaultPlan {
        seed: chaos_seed(),
        worker_panic: 1.0,
        ..FaultPlan::default()
    });
    // serial path: every method panics, every reply is structured
    for i in 0..3 {
        let resp = svc.submit(ApiRequest::new(format!("p{i}"), Method::Models));
        let err = resp.result.unwrap_err();
        assert_eq!(err.code, ErrorCode::Internal);
        assert!(err.message.contains("panicked"), "{}", err.message);
    }
    // batched predict path panics too, and the backend respawns
    let resp = svc.submit(ApiRequest::new(
        "pp",
        Method::Predict(PredictParams { cfg: tiny(), capacity_mib: None, detail: false }),
    ));
    assert_eq!(resp.result.unwrap_err().code, ErrorCode::Internal);
    assert!(svc.metrics().worker_restarts() >= 4, "restarts counted");
    svc.shutdown(); // worker must still exit cleanly

    // at rate 0.5 the service interleaves successes and isolated
    // panics — and stays up for all of them
    let (svc, _faults) = service_with(FaultPlan {
        seed: chaos_seed(),
        worker_panic: 0.5,
        ..FaultPlan::default()
    });
    let (mut ok, mut panicked) = (0, 0);
    for i in 0..32 {
        match svc.submit(ApiRequest::new(format!("m{i}"), Method::Models)).result {
            Ok(_) => ok += 1,
            Err(e) => {
                assert_eq!(e.code, ErrorCode::Internal);
                panicked += 1;
            }
        }
    }
    assert_eq!(ok + panicked, 32, "every request answered");
    assert!(ok > 0 && panicked > 0, "rate 0.5 should mix ({ok} ok, {panicked} panics)");
    svc.shutdown();
}

/// A worker respawn must invalidate the response cache — nothing a
/// poisoned backend computed may be served afterwards. The panic is
/// injected *deterministically*: `FaultState::roll` is a pure function
/// of (seed, site, per-site arrival number), so a twin probe state
/// scans for a seed whose WorkerPanic sequence is exactly
/// [ok, ok, panic, ok], and the service under test replays it.
#[test]
fn worker_respawn_invalidates_response_cache() {
    let plan_for = |seed| FaultPlan { seed, worker_panic: 0.5, ..FaultPlan::default() };
    // Only worker_panic has a nonzero rate, and zero-rate sites never
    // consume arrivals — so job N draws WorkerPanic roll N, on the
    // probe and on the service alike.
    let seed = (0..10_000u64)
        .find(|&s| {
            let probe = FaultState::new(plan_for(s));
            (0..4).map(|_| probe.roll(Site::WorkerPanic)).collect::<Vec<_>>()
                == [false, false, true, false]
        })
        .expect("some seed yields the [ok, ok, panic, ok] sequence");
    eprintln!("respawn-invalidation seed: {seed}");
    let (svc, _faults) = service_with(plan_for(seed));
    let modality = |id: &str| {
        ApiRequest::new(id, Method::Modality(api::ModalityParams { cfg: tiny() }))
    };

    // roll 1 (ok): cold modality — computed and cached
    let first = svc.submit(modality("m1")).result.expect("cold modality").to_string();
    // roll 2 (ok): served from the cache, byte-identical
    let second = svc.submit(modality("m2")).result.expect("cached modality").to_string();
    assert_eq!(first, second, "cache hit diverged from the cold answer");
    assert_eq!(svc.metrics().response_cache(), (1, 1), "second modality was a hit");

    // roll 3 (panic): the predict batch panics -> respawn + cache clear.
    // (The predict's own cache consult records one more miss first.)
    let boom = svc.submit(ApiRequest::new(
        "p1",
        Method::Predict(PredictParams { cfg: tiny(), capacity_mib: None, detail: false }),
    ));
    assert_eq!(boom.result.unwrap_err().code, ErrorCode::Internal);
    assert_eq!(svc.metrics().worker_restarts(), 1, "backend respawned exactly once");

    // roll 4 (ok): the cleared cache recomputes — a miss again, and the
    // recomputed payload must match the pre-panic bytes exactly.
    let third = svc.submit(modality("m3")).result.expect("recomputed modality").to_string();
    assert_eq!(first, third, "post-respawn recompute diverged");
    assert_eq!(
        svc.metrics().response_cache(),
        (1, 3),
        "respawn cleared the cache: m3 was a miss, not a stale hit"
    );
    svc.shutdown();
}

/// An expired deadline is a structured `deadline_exceeded` on both the
/// serial and the batched-predict path; a generous one succeeds.
#[test]
fn deadlines_produce_structured_timeouts() {
    // injected 30ms of latency vs a 5ms deadline: deterministic expiry
    let (svc, _faults) = service_with(FaultPlan {
        seed: chaos_seed(),
        latency: 1.0,
        latency_ms: 30,
        ..FaultPlan::default()
    });
    let resp = svc.submit(ApiRequest::new("d1", Method::Models).with_deadline_ms(5));
    let err = resp.result.unwrap_err();
    assert_eq!(err.code, ErrorCode::DeadlineExceeded, "{err}");
    let resp = svc.submit(
        ApiRequest::new(
            "d2",
            Method::Predict(PredictParams { cfg: tiny(), capacity_mib: None, detail: false }),
        )
        .with_deadline_ms(5),
    );
    assert_eq!(resp.result.unwrap_err().code, ErrorCode::DeadlineExceeded);
    assert!(svc.metrics().deadlines_exceeded() >= 2);

    // plenty of budget: the same requests succeed
    let resp = svc.submit(ApiRequest::new("d3", Method::Models).with_deadline_ms(60_000));
    assert!(resp.result.is_ok());
    svc.shutdown();
}

/// A live-but-tight deadline degrades `plan`/`sweep` to analytical-only
/// answers, explicitly marked — never a silently coarser result.
#[test]
fn tight_deadlines_degrade_plan_and_sweep_with_markers() {
    let svc = PredictionService::start_analytical(ServiceConfig::default());

    // 450ms: ample to execute analytically, below the 500ms simulator
    // headroom — the degraded tier must answer, marked.
    let payload = svc.submit(plan_request(Some(450))).into_result().expect("degraded plan");
    assert!(
        matches!(payload.get("degraded"), Some(Json::Bool(true))),
        "plan payload missing degraded marker: {payload}"
    );
    assert!(payload.get("degraded_reason").is_some());
    assert_eq!(
        payload
            .get("stats")
            .and_then(|s| s.get("sim_points"))
            .and_then(Json::as_u64),
        Some(0),
        "degraded plan must not simulate"
    );

    let payload = svc.submit(sweep_request(Some(450))).into_result().expect("degraded sweep");
    assert!(matches!(payload.get("degraded"), Some(Json::Bool(true))));
    for pt in payload.get("points").unwrap().as_arr().unwrap() {
        assert!(pt.get("predicted_mib").is_some());
        assert!(
            pt.get("measured_mib").is_none(),
            "degraded sweep points must not fake measurements"
        );
    }
    assert!(svc.metrics().degraded() >= 2);

    // without a deadline the same requests answer full-fidelity
    let payload = svc.submit(plan_request(None)).into_result().expect("full plan");
    assert!(payload.get("degraded").is_none());
    assert!(
        payload
            .get("stats")
            .and_then(|s| s.get("sim_points"))
            .and_then(Json::as_u64)
            .unwrap_or(0)
            > 0
    );
    let payload = svc.submit(sweep_request(None)).into_result().expect("full sweep");
    assert!(payload.get("degraded").is_none());
    for pt in payload.get("points").unwrap().as_arr().unwrap() {
        assert!(pt.get("measured_mib").is_some());
    }
    svc.shutdown();
}

/// `over_capacity` — whether from a genuinely full queue or an injected
/// queue-reject burst — carries a `retry_after_ms` hint on the wire.
#[test]
fn over_capacity_carries_retry_hint() {
    let (svc, _faults) = service_with(FaultPlan {
        seed: chaos_seed(),
        queue_reject: 1.0,
        ..FaultPlan::default()
    });
    for resp in [
        svc.try_submit(ApiRequest::new("q1", Method::Models)),
        svc.submit(ApiRequest::new("q2", Method::Models)),
    ] {
        let text = resp.to_json().to_string();
        let err = resp.result.unwrap_err();
        assert_eq!(err.code, ErrorCode::OverCapacity);
        assert!(err.retry_after_ms.unwrap_or(0) > 0, "hint present and positive");
        assert!(text.contains("retry_after_ms"), "hint on the wire: {text}");
    }
    svc.shutdown();
}

/// `health` reports liveness, queue state and fault-injection status.
#[test]
fn health_reports_liveness_and_fault_state() {
    let (svc, faults) = service_with(FaultPlan {
        seed: chaos_seed(),
        internal: 1.0,
        ..FaultPlan::default()
    });
    // health itself must not be faultable into uselessness — but the
    // dispatch_internal failpoint sits in front of every method, so
    // under internal=1.0 it answers `internal` (structured, not a hang).
    let resp = svc.submit(ApiRequest::new("h0", Method::Health));
    assert_eq!(resp.result.unwrap_err().code, ErrorCode::Internal);
    assert!(faults.injected() > 0);
    svc.shutdown();

    let svc = PredictionService::start_analytical(ServiceConfig::default());
    let payload = svc.submit(ApiRequest::new("h1", Method::Health)).into_result().unwrap();
    assert!(matches!(payload.get("status"), Some(Json::Str(s)) if s == "ok"), "{payload}");
    assert_eq!(payload.get("queue_depth").and_then(Json::as_u64), Some(0));
    let f = payload.get("faults").expect("faults block");
    assert!(matches!(f.get("active"), Some(Json::Bool(false))));
    assert_eq!(f.get("injected").and_then(Json::as_u64), Some(0));
    svc.shutdown();
}

/// Satellite 3a: shutdown drains a slow in-flight request — the client
/// still gets its answer even though shutdown began mid-execution.
#[test]
fn shutdown_drains_in_flight_slow_requests() {
    let (svc, _faults) = service_with(FaultPlan {
        seed: chaos_seed(),
        latency: 1.0,
        latency_ms: 300,
        ..FaultPlan::default()
    });
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = api::serve::serve(
        listener,
        svc,
        &ServeOptions { conn_threads: 2, ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();

    let slow = std::thread::spawn(move || {
        let mut client = RawClient::connect(addr);
        match client.call(r#"{"v":1,"id":"slow","method":"models"}"#) {
            Outcome::Response(resp) => {
                assert_eq!(resp.id.as_deref(), Some("slow"));
                assert!(resp.result.is_ok(), "in-flight request answered during drain");
            }
            Outcome::Disconnect => panic!("in-flight request dropped by shutdown"),
        }
    });
    // let the request reach the worker (it then sleeps 300ms injected)
    std::thread::sleep(Duration::from_millis(100));
    let t0 = Instant::now();
    server.shutdown();
    let dt = t0.elapsed();
    slow.join().expect("slow client");
    assert!(dt < Duration::from_secs(10), "drain took {dt:?}");
}

/// Satellite 3b: a client that stops reading its socket cannot pin
/// shutdown — the write timeout cuts the connection.
#[test]
fn non_reading_client_cannot_pin_shutdown() {
    let svc = PredictionService::start_analytical(ServiceConfig::default());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = api::serve::serve(
        listener,
        svc,
        &ServeOptions {
            conn_threads: 2,
            write_timeout: Duration::from_millis(250),
        },
    )
    .unwrap();
    let addr = server.addr();

    // Flood requests and never read a byte of response: the server's
    // answers fill the socket buffers until its write blocks, and only
    // the write timeout can release that connection thread.
    let flood = TcpStream::connect(addr).unwrap();
    flood
        .set_write_timeout(Some(Duration::from_millis(100)))
        .unwrap();
    let mut w = flood.try_clone().unwrap();
    let req = b"{\"v\":1,\"method\":\"models\"}\n";
    for _ in 0..20_000 {
        if w.write_all(req).is_err() {
            break; // our own send buffer filled: the server is wedged
        }
    }
    // give the server time to wedge on the unread responses
    std::thread::sleep(Duration::from_millis(400));

    let t0 = Instant::now();
    server.shutdown();
    let dt = t0.elapsed();
    assert!(
        dt < Duration::from_secs(10),
        "shutdown pinned by a non-reading client: {dt:?}"
    );
    drop(flood);
}

/// With no fault plan, none of the robustness machinery leaks into
/// responses: no degraded markers, no retry hints, health reports ok.
#[test]
fn inert_plan_leaves_responses_untouched() {
    let svc = PredictionService::start_analytical(ServiceConfig::default());
    for req in [
        ApiRequest::new(
            "i1",
            Method::Predict(PredictParams { cfg: tiny(), capacity_mib: None, detail: true }),
        ),
        plan_request(None),
        sweep_request(None),
    ] {
        let resp = svc.submit(req);
        let text = resp.to_json().to_string();
        assert!(resp.result.is_ok());
        assert!(!text.contains("degraded"), "{text}");
        assert!(!text.contains("retry_after_ms"), "{text}");
    }
    assert_eq!(svc.metrics().degraded(), 0);
    assert_eq!(svc.metrics().deadlines_exceeded(), 0);
    assert_eq!(svc.metrics().worker_restarts(), 0);
    svc.shutdown();
}
