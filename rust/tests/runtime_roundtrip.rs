//! AOT round-trip: the HLO-text artifacts produced by `make artifacts`
//! load, compile and execute via PJRT, and agree with the pure-Rust
//! analytical mirror to float tolerance. Skips (with a loud message) if
//! artifacts have not been built.

use mmpredict::config::{Stage, TrainConfig};
use mmpredict::parser::{self, features};
use mmpredict::predictor::{analytical, tensorized::TensorizedPredictor};

fn artifacts_dir() -> Option<String> {
    let dir = mmpredict::runtime::default_artifacts_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir}/ — run `make artifacts`");
        None
    }
}

#[test]
fn tensorized_matches_analytical() {
    let Some(dir) = artifacts_dir() else { return };
    let tp = TensorizedPredictor::load(&dir).unwrap();
    let cfgs = [
        TrainConfig::fig2a(1),
        TrainConfig::fig2a(8),
        TrainConfig::fig2b(4),
        TrainConfig {
            stage: Stage::Pretrain,
            ..TrainConfig::fig2a(2)
        },
        TrainConfig {
            model: "llava-1.5-13b".into(),
            ..TrainConfig::fig2b(8)
        },
        TrainConfig {
            model: "llava-tiny".into(),
            mbs: 2,
            seq_len: 64,
            ..TrainConfig::llava_finetune_default()
        },
    ];
    for cfg in &cfgs {
        let t = tp.predict(cfg).unwrap();
        let pm = parser::parse(cfg).unwrap();
        let a = analytical::predict_encoded(&features::encode(&pm, cfg));
        let rel = |x: f32, y: f32| (x - y).abs() / y.abs().max(1.0);
        assert!(rel(t.peak_mib, a.peak_mib) < 1e-4, "peak {} vs {}", t.peak_mib, a.peak_mib);
        assert!(rel(t.param_mib, a.param_mib) < 1e-4);
        assert!(rel(t.grad_mib, a.grad_mib) < 1e-4);
        assert!(rel(t.opt_mib, a.opt_mib) < 1e-4);
        assert!(rel(t.act_mib, a.act_mib) < 1e-4);
        assert!(rel(t.transient_mib, a.transient_mib) < 1e-4);
    }
}

#[test]
fn batched_execution_matches_single() {
    let Some(dir) = artifacts_dir() else { return };
    let tp = TensorizedPredictor::load(&dir).unwrap();
    let cfgs: Vec<TrainConfig> = (1..=8).map(TrainConfig::fig2b).collect();
    let batched = tp.predict_many(&cfgs).unwrap();
    assert_eq!(batched.len(), 8);
    for (cfg, b) in cfgs.iter().zip(&batched) {
        let single = tp.predict(cfg).unwrap();
        assert!((single.peak_mib - b.peak_mib).abs() < 0.5);
    }
    // peaks strictly decreasing across DP under ZeRO-2
    for w in batched.windows(2) {
        assert!(w[1].peak_mib < w[0].peak_mib);
    }
}

#[test]
fn oversized_batches_are_chunked() {
    let Some(dir) = artifacts_dir() else { return };
    let tp = TensorizedPredictor::load(&dir).unwrap();
    // 13 requests > largest batch capacity (8): must chunk transparently.
    let cfgs: Vec<TrainConfig> = (0..13)
        .map(|i| TrainConfig::fig2a((i % 8) + 1))
        .collect();
    let out = tp.predict_many(&cfgs).unwrap();
    assert_eq!(out.len(), 13);
    // order preserved: same dp -> same prediction
    assert!((out[0].peak_mib - out[8].peak_mib).abs() < 0.5);
}

#[test]
fn manifest_schema_guard() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = mmpredict::runtime::Manifest::load(&dir).unwrap();
    assert_eq!(manifest.num_features, features::NUM_FEATURES);
    assert_eq!(manifest.num_overheads, features::NUM_OVERHEADS);
    assert_eq!(manifest.num_outputs, features::NUM_OUTPUTS);
    assert!(!manifest.variants.is_empty());
    // every declared artifact file exists
    for v in &manifest.variants {
        assert!(
            std::path::Path::new(&format!("{dir}/{}", v.file)).exists(),
            "missing {}",
            v.file
        );
    }
}

#[test]
fn schema_mismatch_is_rejected_loudly() {
    let Some(dir) = artifacts_dir() else { return };
    // Doctor a manifest claiming a different feature schema; Runtime must
    // refuse to load rather than silently mis-marshal.
    let tmp = std::env::temp_dir().join(format!("mmpredict_schema_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest = std::fs::read_to_string(format!("{dir}/manifest.json")).unwrap();
    let doctored = manifest.replace("\"num_features\": 20", "\"num_features\": 19");
    std::fs::write(tmp.join("manifest.json"), doctored).unwrap();
    let err = mmpredict::runtime::Runtime::load(tmp.to_str().unwrap())
        .err()
        .expect("doctored schema must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("schema mismatch"), "got: {msg}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn missing_artifacts_error_mentions_make() {
    let err = mmpredict::runtime::Runtime::load("/nonexistent/dir")
        .err()
        .expect("missing artifacts must be an error");
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "got: {msg}");
}

#[test]
fn corrupt_hlo_file_fails_at_load_not_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join(format!("mmpredict_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(
        format!("{dir}/manifest.json"),
        tmp.join("manifest.json"),
    )
    .unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "txt").unwrap_or(false) {
            std::fs::write(tmp.join(p.file_name().unwrap()), "NOT VALID HLO").unwrap();
        }
    }
    assert!(mmpredict::runtime::Runtime::load(tmp.to_str().unwrap()).is_err());
    std::fs::remove_dir_all(&tmp).ok();
}
