//! OoM guard: the deployment scenario the paper motivates — a scheduler
//! front-end that screens a queue of training-job submissions against
//! GPU capacity *before* any cluster time is spent.
//!
//! Spins up the batched prediction service (PJRT-backed), submits a
//! mixed queue of job configurations from many client threads, and
//! prints an admit/reject decision per job plus service metrics
//! (batching efficiency, latency).
//!
//! Run: `cargo run --release --example oom_guard`

use anyhow::Result;
use mmpredict::config::{Stage, TrainConfig};
use mmpredict::coordinator::{PredictionService, ServiceConfig};
use mmpredict::util::units::human_mib;

const GPU_CAPACITY_MIB: f32 = 80.0 * 1024.0; // H100 80GB

fn job_queue() -> Vec<(String, TrainConfig)> {
    let mut jobs = Vec::new();
    for dp in [1, 2, 4, 8] {
        jobs.push((format!("llava7b-ft-s2048-mbs8-dp{dp}"), TrainConfig::fig2b(dp)));
    }
    for dp in [4, 8] {
        jobs.push((format!("llava7b-ft-s1024-mbs16-dp{dp}"), TrainConfig::fig2a(dp)));
    }
    let mut pt = TrainConfig::fig2a(2);
    pt.stage = Stage::Pretrain;
    jobs.push(("llava7b-pretrain-dp2".into(), pt));
    let mut big = TrainConfig::fig2b(2);
    big.model = "llava-1.5-13b".into();
    jobs.push(("llava13b-ft-dp2".into(), big));
    jobs
}

fn main() -> Result<()> {
    let service = PredictionService::start("artifacts", ServiceConfig::default())?;
    println!("prediction service up\n");

    // Concurrent submissions, as a scheduler would issue them.
    let mut handles = Vec::new();
    for (name, cfg) in job_queue() {
        let client = service.client();
        handles.push(std::thread::spawn(move || {
            let p = client.predict(cfg)?;
            Ok::<_, anyhow::Error>((name, p))
        }));
    }

    println!(
        "{:<28} {:>14} {:>14} {:>8}",
        "job", "predicted", "capacity", "verdict"
    );
    let mut admitted = 0;
    let mut rejected = 0;
    for h in handles {
        let (name, p) = h.join().expect("client thread")?;
        let ok = p.fits(GPU_CAPACITY_MIB);
        if ok {
            admitted += 1;
        } else {
            rejected += 1;
        }
        println!(
            "{:<28} {:>14} {:>14} {:>8}",
            name,
            human_mib(p.peak_mib as f64),
            human_mib(GPU_CAPACITY_MIB as f64),
            if ok { "ADMIT" } else { "REJECT" }
        );
    }

    println!(
        "\n{admitted} admitted, {rejected} rejected (would have OoM'd and wasted cluster time)"
    );
    println!("service metrics: {}", service.metrics().summary());
    service.shutdown();
    Ok(())
}
