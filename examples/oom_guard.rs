//! OoM guard: the deployment scenario the paper motivates — a scheduler
//! front-end that screens a queue of training-job submissions against
//! GPU capacity *before* any cluster time is spent.
//!
//! With AOT artifacts present (`make artifacts`), spins up the batched
//! PJRT prediction service and submits the queue from many client
//! threads. Without them, it screens the same queue through the
//! parallel sweep engine: the analytical predictor decides admit/reject
//! and the simulator cross-checks every verdict, fanned across cores
//! with one reusable `SimContext` per worker.
//!
//! Run: `cargo run --release --example oom_guard`

use std::time::Instant;

use anyhow::Result;
use mmpredict::config::{Stage, TrainConfig};
use mmpredict::coordinator::{PredictionService, ServiceConfig};
use mmpredict::util::units::human_mib;
use mmpredict::{predictor, sweep};

const GPU_CAPACITY_MIB: f64 = 80.0 * 1024.0; // H100 80GB

fn job_queue() -> Vec<(String, TrainConfig)> {
    let mut jobs = Vec::new();
    for dp in [1, 2, 4, 8] {
        jobs.push((format!("llava7b-ft-s2048-mbs8-dp{dp}"), TrainConfig::fig2b(dp)));
    }
    for dp in [4, 8] {
        jobs.push((format!("llava7b-ft-s1024-mbs16-dp{dp}"), TrainConfig::fig2a(dp)));
    }
    let mut pt = TrainConfig::fig2a(2);
    pt.stage = Stage::Pretrain;
    jobs.push(("llava7b-pretrain-dp2".into(), pt));
    let mut big = TrainConfig::fig2b(2);
    big.model = "llava-1.5-13b".into();
    jobs.push(("llava13b-ft-dp2".into(), big));
    jobs
}

fn print_verdict(name: &str, predicted_mib: f64, admitted: &mut u32, rejected: &mut u32) {
    let ok = predicted_mib <= GPU_CAPACITY_MIB;
    if ok {
        *admitted += 1;
    } else {
        *rejected += 1;
    }
    println!(
        "{:<28} {:>14} {:>14} {:>8}",
        name,
        human_mib(predicted_mib),
        human_mib(GPU_CAPACITY_MIB),
        if ok { "ADMIT" } else { "REJECT" }
    );
}

/// Screen through the batched PJRT service (needs artifacts).
fn run_service(jobs: Vec<(String, TrainConfig)>, service: PredictionService) -> Result<()> {
    println!("prediction service up\n");
    let mut handles = Vec::new();
    for (name, cfg) in jobs {
        let client = service.client();
        handles.push(std::thread::spawn(move || {
            let p = client.predict(cfg)?;
            Ok::<_, anyhow::Error>((name, p))
        }));
    }

    println!(
        "{:<28} {:>14} {:>14} {:>8}",
        "job", "predicted", "capacity", "verdict"
    );
    let (mut admitted, mut rejected) = (0, 0);
    for h in handles {
        let (name, p) = h.join().expect("client thread")?;
        print_verdict(&name, p.peak_mib as f64, &mut admitted, &mut rejected);
    }
    println!(
        "\n{admitted} admitted, {rejected} rejected (would have OoM'd and wasted cluster time)"
    );
    println!("service metrics: {}", service.metrics().summary());
    service.shutdown();
    Ok(())
}

/// Screen through the parallel sweep engine (no artifacts required).
fn run_sweep(jobs: Vec<(String, TrainConfig)>) -> Result<()> {
    let cfgs: Vec<TrainConfig> = jobs.iter().map(|(_, c)| c.clone()).collect();
    let engine = sweep::Sweep::default();
    let t0 = Instant::now();
    let rows = engine.run(&cfgs, |ctx, pm, cfg| {
        let predicted = predictor::predict(cfg)?.peak_mib as f64;
        let measured = ctx.simulate_parsed(pm, cfg)?.peak_mib;
        Ok((predicted, measured))
    })?;
    let dt = t0.elapsed();

    println!(
        "{:<28} {:>14} {:>14} {:>14} {:>8}",
        "job", "predicted", "simulated", "capacity", "verdict"
    );
    let (mut admitted, mut rejected) = (0, 0);
    let mut disagreements = 0;
    for ((name, _), (predicted, measured)) in jobs.iter().zip(&rows) {
        let ok = *predicted <= GPU_CAPACITY_MIB;
        if ok {
            admitted += 1;
        } else {
            rejected += 1;
        }
        // cross-check the verdict against the simulator ground truth
        if ok != (*measured <= GPU_CAPACITY_MIB) {
            disagreements += 1;
        }
        println!(
            "{:<28} {:>14} {:>14} {:>14} {:>8}",
            name,
            human_mib(*predicted),
            human_mib(*measured),
            human_mib(GPU_CAPACITY_MIB),
            if ok { "ADMIT" } else { "REJECT" }
        );
    }
    println!(
        "\n{admitted} admitted, {rejected} rejected (would have OoM'd and wasted cluster time)"
    );
    println!(
        "{} jobs screened in {:.3?} on {} worker threads ({:.0} jobs/s), {} predictor/simulator verdict disagreements",
        jobs.len(),
        dt,
        engine.threads().min(jobs.len()),
        jobs.len() as f64 / dt.as_secs_f64(),
        disagreements
    );
    Ok(())
}

fn main() -> Result<()> {
    let jobs = job_queue();
    match PredictionService::start("artifacts", ServiceConfig::default()) {
        Ok(service) => run_service(jobs, service),
        Err(e) => {
            eprintln!("PJRT service unavailable ({e:#}); screening via the parallel sweep engine\n");
            run_sweep(jobs)
        }
    }
}
