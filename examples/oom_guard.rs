//! OoM guard: the deployment scenario the paper motivates — a scheduler
//! front-end that screens a queue of training-job submissions against
//! GPU capacity *before* any cluster time is spent, and answers the
//! follow-up question every rejected user asks: "so what WOULD fit?"
//!
//! Since the wire-API redesign the guard talks to the service in the
//! v1 envelope itself: every screening question is an `ApiRequest`
//! (`method: "predict"`, id-correlated per job), remediation and
//! capacity publishing are `"plan"` requests, and the replies are
//! decoded with the same `api::codec` the NDJSON server uses — so this
//! example exercises exactly the protocol a remote scheduler would
//! speak against `repro serve`, minus the TCP socket.
//!
//! Run: `cargo run --release --example oom_guard`

use anyhow::{anyhow, Result};
use mmpredict::api::codec;
use mmpredict::api::{ApiRequest, Method, PlanParams, PredictParams};
use mmpredict::config::{Stage, TrainConfig};
use mmpredict::coordinator::{PredictionService, ServiceConfig};
use mmpredict::planner::{Axes, PlanRequest};
use mmpredict::util::units::human_mib;
use mmpredict::{report, sweep};

const GPU_CAPACITY_MIB: f64 = 80.0 * 1024.0; // H100 80GB

fn job_queue() -> Vec<(String, TrainConfig)> {
    let mut jobs = Vec::new();
    for dp in [1, 2, 4, 8] {
        jobs.push((format!("llava7b-ft-s2048-mbs8-dp{dp}"), TrainConfig::fig2b(dp)));
    }
    for dp in [4, 8] {
        jobs.push((format!("llava7b-ft-s1024-mbs16-dp{dp}"), TrainConfig::fig2a(dp)));
    }
    let mut pt = TrainConfig::fig2a(2);
    pt.stage = Stage::Pretrain;
    jobs.push(("llava7b-pretrain-dp2".into(), pt));
    let mut big = TrainConfig::fig2b(2);
    big.model = "llava-1.5-13b".into();
    jobs.push(("llava13b-ft-dp2".into(), big));
    jobs
}

/// Ask the service for a plan via the wire envelope and decode the
/// typed frontier back out of the payload.
fn plan_via_envelope(
    service: &PredictionService,
    req: PlanRequest,
) -> Result<mmpredict::planner::Plan> {
    let base = req.base.clone();
    let resp = service.submit(ApiRequest::new("plan", Method::Plan(PlanParams { req })));
    let payload = resp.into_result()?;
    Ok(codec::plan_from_json(&payload, &base)?)
}

fn main() -> Result<()> {
    let service = match PredictionService::start("artifacts", ServiceConfig::default()) {
        Ok(s) => {
            println!("prediction service up (tensorized AOT backend)\n");
            s
        }
        Err(e) => {
            eprintln!("PJRT artifacts unavailable ({e:#}); using the analytical backend\n");
            PredictionService::start_analytical(ServiceConfig::default())
        }
    };

    // -- 1. screen the submission queue: one id-correlated "predict"
    //       envelope per job, fired from concurrent clients (batched by
    //       the service exactly as wire traffic would be) -------------
    let jobs = job_queue();
    let mut handles = Vec::new();
    for (name, cfg) in &jobs {
        let client = service.client();
        let (name, cfg) = (name.clone(), cfg.clone());
        handles.push(std::thread::spawn(move || {
            let req = ApiRequest::new(
                name.clone(),
                Method::Predict(PredictParams {
                    cfg: cfg.clone(),
                    capacity_mib: Some(GPU_CAPACITY_MIB),
                    detail: false,
                }),
            );
            let resp = client.submit(req);
            if resp.id.as_deref() != Some(name.as_str()) {
                return Err(anyhow!("response correlation broken for {name}"));
            }
            let payload = resp.into_result()?;
            let p = codec::prediction_from_json(
                payload
                    .get("prediction")
                    .ok_or_else(|| anyhow!("predict payload missing prediction"))?,
            )?;
            Ok::<_, anyhow::Error>((name, cfg, p))
        }));
    }

    let screened: Vec<(String, TrainConfig, f64)> = handles
        .into_iter()
        .map(|h| {
            let (name, cfg, p) = h.join().expect("client thread")?;
            Ok::<_, anyhow::Error>((name, cfg, p.peak_mib as f64))
        })
        .collect::<Result<_>>()?;

    // Cross-check every verdict against the ground-truth simulator (the
    // guard's safety net: a predictor under-estimate here is exactly the
    // OOM the guard exists to prevent).
    let cfgs: Vec<TrainConfig> = screened.iter().map(|(_, c, _)| c.clone()).collect();
    let measured = sweep::simulate_grid(&cfgs)?;

    println!(
        "{:<28} {:>14} {:>14} {:>14} {:>8}",
        "job", "predicted", "simulated", "capacity", "verdict"
    );
    let (mut admitted, mut disagreements, mut rejected_jobs) = (0u32, 0u32, Vec::new());
    for ((name, cfg, predicted), m) in screened.into_iter().zip(&measured) {
        let ok = predicted <= GPU_CAPACITY_MIB;
        if ok != (m.peak_mib <= GPU_CAPACITY_MIB) {
            disagreements += 1;
        }
        println!(
            "{:<28} {:>14} {:>14} {:>14} {:>8}",
            name,
            human_mib(predicted),
            human_mib(m.peak_mib),
            human_mib(GPU_CAPACITY_MIB),
            if ok { "ADMIT" } else { "REJECT" }
        );
        if ok {
            admitted += 1;
        } else {
            rejected_jobs.push((name, cfg));
        }
    }
    println!(
        "\n{admitted} admitted, {} rejected (would have OoM'd and wasted cluster time), \
         {disagreements} predictor/simulator verdict disagreements\n",
        rejected_jobs.len()
    );

    // -- 2. remediation: for each reject, a "plan" envelope asks for the
    //       largest safe micro-batch at the job's own geometry ---------
    for (name, cfg) in &rejected_jobs {
        let axes = Axes {
            mbs: vec![1, 2, 4, 8, 16, 32],
            ..Axes::fixed(cfg)
        };
        let plan = plan_via_envelope(
            &service,
            PlanRequest {
                base: cfg.clone(),
                budget_mib: GPU_CAPACITY_MIB,
                axes,
            },
        )?;
        match plan.recommended().next() {
            Some(best) => println!(
                "{name}: resubmit with mbs {} -> {} simulated ({} headroom)",
                best.cfg.mbs,
                human_mib(best.simulated_mib),
                human_mib(best.headroom_mib)
            ),
            None => println!(
                "{name}: no micro-batch fits — needs more DP/ZeRO sharding or a smaller model"
            ),
        }
    }

    // -- 3. publish the GPU's capacity frontier: the maximal safe LLaVA
    //       fine-tune configs, ranked by throughput --------------------
    let base = TrainConfig::llava_finetune_default();
    let plan = plan_via_envelope(
        &service,
        PlanRequest {
            axes: Axes::standard(&base),
            base,
            budget_mib: GPU_CAPACITY_MIB,
        },
    )?;
    println!(
        "\n== capacity frontier: llava-1.5-7b fine-tune under {} ==",
        human_mib(GPU_CAPACITY_MIB)
    );
    println!("{}", report::frontier_table(&plan, 10, false).render());
    println!(
        "frontier found with {} simulations instead of the {}-point full grid",
        plan.stats.sim_points, plan.stats.grid_points
    );

    println!("\nservice metrics: {}", service.metrics().summary());
    service.shutdown();
    Ok(())
}
