//! LoRA fine-tuning memory planning (paper §5 future work, implemented):
//! sweep adapter ranks and find the largest micro-batch size that fits a
//! given GPU — the question a practitioner actually asks.
//!
//! Run: `cargo run --release --example lora_finetune`

use anyhow::Result;
use mmpredict::config::{Stage, TrainConfig};
use mmpredict::model::lora::LoraConfig;
use mmpredict::report::Table;
use mmpredict::{predictor, simulator};

const GPU_MIB: f64 = 80.0 * 1024.0;

fn lora_cfg(rank: u64, mbs: u64) -> TrainConfig {
    TrainConfig {
        stage: Stage::LoraFinetune,
        lora: Some(LoraConfig { rank, ..Default::default() }),
        mbs,
        ..TrainConfig::fig2b(1) // single GPU: the tightest case
    }
}

fn main() -> Result<()> {
    println!("== LoRA rank sweep (LLaVA-1.5-7B, SeqLen 2048, MBS 8, single GPU) ==\n");
    let mut t = Table::new(vec![
        "rank", "trainable (M)", "predicted", "measured", "APE %", "vs full-FT",
    ]);
    let full = simulator::simulate(&TrainConfig::fig2b(1))?.peak_mib;
    for rank in [8, 16, 64, 128, 256] {
        let cfg = lora_cfg(rank, 8);
        let pm = mmpredict::parser::parse(&cfg)?;
        let p = predictor::predict(&cfg)?.peak_mib as f64;
        let m = simulator::simulate(&cfg)?.peak_mib;
        t.row(vec![
            rank.to_string(),
            format!("{:.1}", pm.trainable_param_elems as f64 / 1e6),
            format!("{:.2} GiB", p / 1024.0),
            format!("{:.2} GiB", m / 1024.0),
            format!("{:.1}", mmpredict::report::ape(p, m) * 100.0),
            format!("{:.2}x", m / full),
        ]);
    }
    println!("{}", t.render());
    println!("(full fine-tuning on one GPU measures {:.2} GiB)\n", full / 1024.0);

    println!("== largest MBS that fits 80 GiB at rank 64 ==\n");
    let mut best = None;
    for mbs in [1u64, 2, 4, 8, 16, 32, 64] {
        let p = predictor::predict(&lora_cfg(64, mbs))?;
        let fits = (p.peak_mib as f64) <= GPU_MIB;
        println!(
            "mbs {mbs:>3}: predicted {:>9.2} GiB  {}",
            p.peak_mib as f64 / 1024.0,
            if fits { "fits" } else { "OoM" }
        );
        if fits {
            best = Some(mbs);
        }
    }
    match best {
        Some(mbs) => println!("\n-> plan: micro-batch size {mbs}"),
        None => println!("\n-> does not fit at any micro-batch size"),
    }
    Ok(())
}
