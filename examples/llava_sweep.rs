//! End-to-end driver (ARCHITECTURE.md "Experiment index"): regenerate the
//! paper's full evaluation — Fig. 2a and Fig. 2b sweeps of LLaVA-1.5-7B
//! across DP 1..8 — through the REAL stack: model zoo -> parser ->
//! feature encoding -> **AOT artifact executed via PJRT** (the L1 Pallas
//! factor kernel + liveness scan) -> MAPE against the discrete-event
//! simulator, exactly the paper's headline metric.
//!
//! Requires `make artifacts` (falls back to the analytical mirror with a
//! warning if artifacts are missing).
//!
//! Run: `cargo run --release --example llava_sweep [-- --figure 2a]`

use anyhow::Result;
use mmpredict::config::TrainConfig;
use mmpredict::eval::fig2::run_setting;
use mmpredict::predictor::tensorized::TensorizedPredictor;
use mmpredict::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let which = args.get_or("figure", "all");
    let artifacts = args.get_or("artifacts", "artifacts");

    let tensorized = match TensorizedPredictor::load(artifacts) {
        Ok(tp) => {
            println!(
                "loaded AOT predictor artifacts (PJRT platform: {}, capacities: {:?})\n",
                tp.runtime().platform(),
                tp.runtime().capacities()
            );
            Some(tp)
        }
        Err(e) => {
            eprintln!("WARNING: {e:#}\nfalling back to the analytical mirror\n");
            None
        }
    };
    let predict = |cfg: &TrainConfig| -> Result<f64> {
        match &tensorized {
            Some(tp) => Ok(tp.predict(cfg)?.peak_mib as f64),
            None => Ok(mmpredict::predictor::predict(cfg)?.peak_mib as f64),
        }
    };

    // run_setting parses the 7B model once per setting and fans the
    // eight simulator points across cores (sweep engine); only the
    // predictor runs on this thread.
    std::fs::create_dir_all("results").ok();
    let mut mapes = Vec::new();
    let t0 = std::time::Instant::now();
    let mut points = 0usize;
    if which == "2a" || which == "all" {
        let r = run_setting(
            "fig2a: LLaVA-1.5-7B, SeqLen 1024, MBS 16, ZeRO-2 (paper: ~13% MAPE)",
            TrainConfig::fig2a,
            predict,
        )?;
        println!("{}", r.render());
        std::fs::write("results/fig2a.csv", r.to_csv())?;
        points += r.points.len();
        mapes.push(("fig2a", r.mape));
    }
    if which == "2b" || which == "all" {
        let r = run_setting(
            "fig2b: LLaVA-1.5-7B, SeqLen 2048, MBS 8, ZeRO-2 (paper: ~8.7% MAPE)",
            TrainConfig::fig2b,
            predict,
        )?;
        println!("{}", r.render());
        std::fs::write("results/fig2b.csv", r.to_csv())?;
        points += r.points.len();
        mapes.push(("fig2b", r.mape));
    }
    let dt = t0.elapsed();

    println!("== headline ==");
    for (name, mape) in &mapes {
        println!("{name}: average MAPE {:.1}% (paper band: 8.7%-13%)", mape * 100.0);
    }
    // each 8-point setting runs on min(cores, 8) workers
    println!(
        "{points} sweep points in {dt:.3?} ({:.1} points/s, {} worker threads per setting)",
        points as f64 / dt.as_secs_f64(),
        mmpredict::sweep::default_threads().min(8)
    );
    Ok(())
}
