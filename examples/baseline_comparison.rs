//! Baseline comparison (the paper's §1 motivation, quantified): our
//! factorization predictor vs the unimodal formulation baselines
//! (Fujii-style, LLMem-style) and profiling-based extrapolation, across
//! both Fig. 2 settings and the pre-training stage where unimodal
//! formulas break down hardest.
//!
//! Run: `cargo run --release --example baseline_comparison`

use anyhow::Result;
use mmpredict::baselines::{fujii, llmem, profiling};
use mmpredict::config::{Stage, TrainConfig};
use mmpredict::report::{ape, mape, Table};
use mmpredict::{predictor, simulator};

fn main() -> Result<()> {
    let settings: Vec<(&str, Vec<TrainConfig>)> = vec![
        ("fig2a finetune", (1..=8).map(TrainConfig::fig2a).collect()),
        ("fig2b finetune", (1..=8).map(TrainConfig::fig2b).collect()),
        (
            "pretrain (projector only)",
            (1..=8)
                .map(|dp| TrainConfig {
                    stage: Stage::Pretrain,
                    ..TrainConfig::fig2a(dp)
                })
                .collect(),
        ),
    ];

    let mut summary = Table::new(vec![
        "setting", "ours MAPE %", "fujii MAPE %", "llmem MAPE %", "profiling MAPE %",
    ]);

    for (name, cfgs) in &settings {
        let mut pairs_ours = Vec::new();
        let mut pairs_fujii = Vec::new();
        let mut pairs_llmem = Vec::new();
        let mut pairs_prof = Vec::new();
        for cfg in cfgs {
            let m = simulator::simulate(cfg)?.peak_mib;
            pairs_ours.push((predictor::predict(cfg)?.peak_mib as f64, m));
            pairs_fujii.push((fujii::predict(cfg)?.predicted_mib, m));
            pairs_llmem.push((llmem::predict(cfg)?.predicted_mib, m));
            pairs_prof.push((profiling::predict(cfg)?.predicted_mib, m));
        }
        summary.row(vec![
            name.to_string(),
            format!("{:.1}", mape(&pairs_ours) * 100.0),
            format!("{:.1}", mape(&pairs_fujii) * 100.0),
            format!("{:.1}", mape(&pairs_llmem) * 100.0),
            format!("{:.1}", mape(&pairs_prof) * 100.0),
        ]);
    }

    println!("== MAPE by method (lower is better) ==\n");
    println!("{}", summary.render());

    // Spotlight: the paper's specific claim that formula [2] "does not
    // work at all" on a multimodal model.
    let cfg = TrainConfig::fig2a(8);
    let m = simulator::simulate(&cfg)?.peak_mib;
    let f = fujii::predict(&cfg)?.predicted_mib;
    println!(
        "fujii on fig2a/dp8: predicts {:.0} GiB vs measured {:.0} GiB ({:.0}% error)\n\
         profiling cost: ours 0 iterations, profiling baseline {} simulated iterations per setting",
        f / 1024.0,
        m / 1024.0,
        ape(f, m) * 100.0,
        profiling::predict(&cfg)?.profile_iters,
    );
    Ok(())
}
