//! Agentic-AI inference serving (paper §5 future work, implemented):
//! plan KV-cache capacity for a multimodal agent endpoint, then validate
//! the plan against the multi-turn serving simulator.
//!
//! Run: `cargo run --release --example agent_serving`

use anyhow::Result;
use mmpredict::inference::{predict_inference, simulate_serving, InferenceConfig, ServingWorkload};
use mmpredict::report::Table;
use mmpredict::util::units::human_mib;

fn main() -> Result<()> {
    let cfg = InferenceConfig::llava_7b_agent();
    let p = predict_inference(&cfg)?;

    println!("== LLaVA-1.5-7B agent endpoint, context {} ==\n", cfg.context_len);
    println!("weights          {}", human_mib(p.weights_mib));
    println!("KV per token     {:.0} KiB", p.kv_bytes_per_token / 1024.0);
    println!(
        "KV cache         {} ({} seqs x {} ctx)",
        human_mib(p.kv_cache_mib),
        cfg.max_seqs,
        cfg.context_len
    );
    println!("decode workspace {}", human_mib(p.workspace_mib));
    println!("peak             {}\n", human_mib(p.peak_mib));

    println!("== capacity planning across GPUs ==\n");
    let mut t = Table::new(vec!["GPU", "capacity", "max sessions (analytic)"]);
    let gpus = [("L4", 24.0), ("A100-40G", 40.0), ("H100-80G", 80.0), ("H200-141G", 141.0)];
    for (name, gib) in gpus {
        t.row(vec![
            name.to_string(),
            format!("{gib:.0} GiB"),
            p.max_seqs_for(gib * 1024.0, cfg.context_len).to_string(),
        ]);
    }
    println!("{}", t.render());

    println!("== multi-turn serving simulation (H100-80G) ==\n");
    for rate in [0.4, 0.8, 1.6] {
        let wl = ServingWorkload { arrival_rate: rate, ..Default::default() };
        let rep = simulate_serving(&cfg, &wl, 80.0 * 1024.0)?;
        println!(
            "arrival {rate:.1}/tick: peak {} ({} sessions), admitted {}, rejected {} ({:.1}%), completed {}",
            human_mib(rep.peak_mib),
            rep.peak_sessions,
            rep.admitted,
            rep.rejected,
            rep.rejection_rate() * 100.0,
            rep.completed,
        );
    }
    Ok(())
}
