//! Quickstart: predict the peak GPU memory of a LLaVA-1.5-7B fine-tuning
//! run, compare against the simulated measurement, and check whether it
//! fits an 80 GiB GPU.
//!
//! Run: `cargo run --release --example quickstart`

use mmpredict::config::TrainConfig;
use mmpredict::util::units::human_mib;
use mmpredict::{predictor, simulator};

fn main() -> anyhow::Result<()> {
    // The paper's Fig. 2b setting at DP=4: SeqLen 2048, MBS 8, ZeRO-2.
    let cfg = TrainConfig::fig2b(4);

    // 1. Parse the model: modules -> fine-grained layers with training
    //    behaviour (Fig. 1 steps 1-4).
    let parsed = mmpredict::parser::parse(&cfg)?;
    println!(
        "parsed {} into {} layers across {} modules ({:.2}B params, {:.2}B trainable)",
        parsed.model_name,
        parsed.num_layers(),
        parsed.trainable_by_module().len() + 1, // + frozen vision tower
        parsed.total_param_elems as f64 / 1e9,
        parsed.trainable_param_elems as f64 / 1e9,
    );

    // 2. Factor predictor (Fig. 1 steps 5-7): per-layer factorization,
    //    Eq. 1 aggregation.
    let p = predictor::predict(&cfg)?;
    println!("\npredicted peak: {}", human_mib(p.peak_mib as f64));
    println!("  M_param {:>12}", human_mib(p.param_mib as f64));
    println!("  M_grad  {:>12}", human_mib(p.grad_mib as f64));
    println!("  M_opt   {:>12}", human_mib(p.opt_mib as f64));
    println!("  M_act   {:>12}", human_mib(p.act_mib as f64));

    // 3. Ground truth: the discrete-event training-step simulator.
    let m = simulator::simulate(&cfg)?;
    println!("\nsimulated measurement: {}", human_mib(m.peak_mib));
    println!(
        "prediction error: {:.1}%",
        mmpredict::report::ape(p.peak_mib as f64, m.peak_mib) * 100.0
    );

    // 4. The OoM-prevention decision the paper motivates.
    let h100 = 80.0 * 1024.0;
    println!(
        "\nfits one 80 GiB H100: predicted {} / measured {}",
        if p.fits(h100 as f32) { "YES" } else { "NO" },
        if m.peak_mib <= h100 { "YES" } else { "NO" },
    );
    Ok(())
}
