"""Minimal NDJSON client for `repro serve` (mmpredict wire API v1).

One TCP connection, one JSON document per line each way:

    request:  {"v": 1, "id": "py-1", "method": "predict", "params": {...}}
    response: {"v": 1, "id": "py-1", "ok": {...}}
          or  {"v": 1, "id": "py-1", "error": {"code": "...", "message": "..."}}

Usage:

    from client import ReproClient
    with ReproClient(port=7411) as c:
        p = c.predict({"model": "llava-1.5-7b", "mbs": 8, "seq_len": 2048})
        print(p["prediction"]["peak_mib"])
        plan = c.plan({"model": "llava-1.5-7b"}, budget_mib=80 * 1024)
        for cand in plan["candidates"][:3]:
            print(cand["mbs"], cand["simulated_mib"])

Demo (predict + plan round-trip against a running server):

    repro serve --port 7411 &
    python3 python/client.py --port 7411 --demo

Open-loop load generation (requests sent on a fixed arrival schedule,
queueing delay charged to latency; per-method p50/p95/p99 at the end):

    python3 python/client.py --port 7411 --rate 200 --duration 5

Only the standard library is used.
"""

from __future__ import annotations

import argparse
import itertools
import json
import socket
import sys
import threading
import time

WIRE_VERSION = 1


class ApiError(RuntimeError):
    """Structured server-side failure (code + message).

    ``retry_after_ms`` is the server's backoff hint, present on
    ``over_capacity`` responses; ``call`` honors it automatically.
    """

    def __init__(self, code: str, message: str, retry_after_ms: int | None = None):
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message
        self.retry_after_ms = retry_after_ms


class ProtocolError(RuntimeError):
    """The server answered something that is not a valid v1 response."""


class ReproClient:
    """Blocking NDJSON client over one TCP connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 7411, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self.sock.makefile("r", encoding="utf-8", newline="\n")
        self._wfile = self.sock.makefile("w", encoding="utf-8", newline="\n")
        self._ids = itertools.count(1)

    # -- envelope -------------------------------------------------------

    def call(
        self,
        method: str,
        params: dict | None = None,
        deadline_ms: int | None = None,
        max_attempts: int = 3,
    ):
        """Send one request, return the `ok` payload (raises ApiError).

        ``over_capacity`` responses are retried up to ``max_attempts``
        times, sleeping the server's ``retry_after_ms`` hint between
        attempts (pass ``max_attempts=1`` to disable). Other errors
        raise immediately.
        """
        last: ApiError | None = None
        for _attempt in range(max(1, max_attempts)):
            try:
                return self._call_once(method, params, deadline_ms)
            except ApiError as e:
                if e.code != "over_capacity":
                    raise
                last = e
                time.sleep((e.retry_after_ms or 100) / 1000.0)
        assert last is not None
        raise last

    def _call_once(self, method: str, params: dict | None, deadline_ms: int | None):
        rid = f"py-{next(self._ids)}"
        req = {"v": WIRE_VERSION, "id": rid, "method": method}
        if params is not None:
            req["params"] = params
        if deadline_ms is not None:
            req["deadline_ms"] = deadline_ms
        self._wfile.write(json.dumps(req) + "\n")
        self._wfile.flush()
        line = self._rfile.readline()
        if not line:
            raise ProtocolError("server closed the connection")
        resp = json.loads(line)
        if resp.get("v") != WIRE_VERSION:
            raise ProtocolError(f"unexpected wire version in {resp!r}")
        if resp.get("id") != rid:
            raise ProtocolError(f"response id {resp.get('id')!r} != request id {rid!r}")
        if "error" in resp:
            err = resp["error"]
            raise ApiError(
                err.get("code", "internal"),
                err.get("message", ""),
                err.get("retry_after_ms"),
            )
        if "ok" not in resp:
            raise ProtocolError(f"response carries neither ok nor error: {resp!r}")
        return resp["ok"]

    # -- typed conveniences --------------------------------------------

    def predict(self, config: dict, capacity_mib: float | None = None, detail: bool = False):
        params: dict = {"config": config}
        if capacity_mib is not None:
            params["capacity_mib"] = capacity_mib
        if detail:
            params["detail"] = True
        return self.call("predict", params)

    def plan(self, config: dict, budget_mib: float, axes: dict | None = None):
        params: dict = {"config": config, "budget_mib": budget_mib}
        if axes is not None:
            params["axes"] = axes
        return self.call("plan", params)

    def simulate(self, config: dict):
        return self.call("simulate", {"config": config})

    def models(self):
        return self.call("models")["models"]

    def metrics(self):
        return self.call("metrics")

    def health(self):
        """Liveness + pressure snapshot: status, queue depth, fault state."""
        return self.call("health")

    def close(self):
        try:
            self._wfile.close()
            self._rfile.close()
        finally:
            self.sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *_exc):
        self.close()


def _demo(host: str, port: int) -> int:
    """Predict + plan round-trip; exits nonzero on any mismatch."""
    cfg = {"model": "llava-tiny", "mbs": 2, "seq_len": 64}
    with ReproClient(host, port) as c:
        names = [m["name"] for m in c.models()]
        print(f"server models: {', '.join(names)}")

        h = c.health()
        print(f"health: {h['status']}, queue {h['queue_depth']}/{h['queue_capacity']}")
        assert h["status"] in ("ok", "degraded")

        ok = c.predict(cfg, capacity_mib=80 * 1024)
        peak = ok["prediction"]["peak_mib"]
        print(f"predict: peak {peak:.1f} MiB, fits 80 GiB: {ok['fits']}")
        assert peak > 0 and ok["fits"] is True

        plan = c.plan(cfg, budget_mib=1e9, axes={"mbs": [1, 2], "seq_len": [32, 64]})
        cands = plan["candidates"]
        print(f"plan: {len(cands)} candidates, {plan['stats']['sim_points']} simulations")
        assert cands, "expected a non-empty frontier"
        assert all(c_["simulated_mib"] <= 1e9 for c_ in cands)

        # a structured error, not a disconnect
        try:
            c.predict({"model": "not-a-model"})
        except ApiError as e:
            print(f"unknown model answered with code={e.code}")
            assert e.code == "unknown_model"
        else:
            raise AssertionError("expected unknown_model")

        snap = c.metrics()["per_method"]
        print(
            "server counters: predict={} plan={} models={}".format(
                snap["predict"]["requests"], snap["plan"]["requests"], snap["models"]["requests"]
            )
        )
    print("demo OK")
    return 0


def _percentile(sorted_vals: list[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = round((len(sorted_vals) - 1) * p)
    return sorted_vals[min(idx, len(sorted_vals) - 1)]


def _load_mix(i: int) -> tuple[str, dict | None]:
    """The pinned mixed-method cycle: predict-heavy, with the cheap
    snapshots and two slow-tier probes riding along (mirrors the Rust
    `serve_load` bench so numbers are comparable)."""
    pool = [
        {"model": "llava-tiny", "mbs": 1, "seq_len": 32},
        {"model": "llava-tiny", "mbs": 2, "seq_len": 32},
        {"model": "llava-tiny", "mbs": 1, "seq_len": 64},
        {"model": "llava-tiny", "mbs": 2, "seq_len": 64},
    ]
    cfg = pool[i % len(pool)]
    slot = i % 16
    if slot == 10:
        return "models", None
    if slot == 11:
        return "metrics", None
    if slot in (12, 13):
        return "health", None
    if slot == 14:
        return "simulate", {"config": cfg}
    if slot == 15:
        return "modality", {"config": cfg}
    return "predict", {"config": cfg}


def _loadgen(host: str, port: int, rate: float, duration: float) -> int:
    """Open-loop generator over one pipelined connection.

    Requests go out on the fixed `rate` schedule whether or not earlier
    responses have arrived — like an overloaded caller — so queueing
    delay shows up in the reported latency. The server answers each
    connection in request order, so the reader matches responses to
    requests positionally.
    """
    n = max(1, int(rate * duration))
    sock = socket.create_connection((host, port), timeout=60.0)
    rfile = sock.makefile("r", encoding="utf-8", newline="\n")
    wfile = sock.makefile("w", encoding="utf-8", newline="\n")

    recv_times: list[float] = []
    errors: list[str] = []

    def reader() -> None:
        for _ in range(n):
            line = rfile.readline()
            if not line:
                raise ProtocolError("server closed the connection mid-run")
            resp = json.loads(line)
            recv_times.append(time.monotonic())
            if "error" in resp:
                errors.append(resp["error"].get("code", "internal"))

    t = threading.Thread(target=reader, daemon=True)
    t.start()

    methods: list[str] = []
    arrivals: list[float] = []
    period = 1.0 / rate
    t0 = time.monotonic()
    for i in range(n):
        due = t0 + i * period
        now = time.monotonic()
        if now < due:
            time.sleep(due - now)
        method, params = _load_mix(i)
        req: dict = {"v": WIRE_VERSION, "id": f"load-{i}", "method": method}
        if params is not None:
            req["params"] = params
        wfile.write(json.dumps(req) + "\n")
        wfile.flush()
        methods.append(method)
        arrivals.append(due)  # open loop: latency counts from the schedule
    t.join(timeout=60.0)
    if t.is_alive():
        print("FAIL: reader did not drain all responses within 60s", file=sys.stderr)
        return 1
    if len(recv_times) < n:
        print(
            f"FAIL: connection lost after {len(recv_times)}/{n} responses",
            file=sys.stderr,
        )
        return 1

    wall = max(recv_times[-1] - t0, 1e-9)
    per_method: dict[str, list[float]] = {}
    for method, sent, recv in zip(methods, arrivals, recv_times):
        per_method.setdefault(method, []).append(max(recv - sent, 0.0) * 1e3)
    print(
        f"open-loop: offered {rate:.0f} rps for {duration:.1f}s -> "
        f"{n} requests, achieved {n / wall:.1f} rps, {len(errors)} errors"
    )
    print(f"{'method':<10} {'count':>6} {'p50 ms':>9} {'p95 ms':>9} {'p99 ms':>9}")
    for method in sorted(per_method):
        lats = sorted(per_method[method])
        print(
            f"{method:<10} {len(lats):>6} "
            f"{_percentile(lats, 0.50):>9.2f} "
            f"{_percentile(lats, 0.95):>9.2f} "
            f"{_percentile(lats, 0.99):>9.2f}"
        )
    if errors:
        counts: dict[str, int] = {}
        for code in errors:
            counts[code] = counts.get(code, 0) + 1
        print(f"errors: {counts}")
    sock.close()
    return 1 if errors else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7411)
    ap.add_argument("--demo", action="store_true", help="run the predict+plan round-trip demo")
    ap.add_argument("--rate", type=float, help="open-loop load: offered arrival rate (req/s)")
    ap.add_argument(
        "--duration",
        type=float,
        default=5.0,
        help="open-loop load: seconds of traffic to offer (default 5)",
    )
    args = ap.parse_args()
    if args.demo:
        sys.exit(_demo(args.host, args.port))
    if args.rate:
        if args.rate <= 0 or args.duration <= 0:
            ap.error("--rate and --duration must be positive")
        sys.exit(_loadgen(args.host, args.port, args.rate, args.duration))
    ap.print_help()
