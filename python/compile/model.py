"""L2: the full peak-memory prediction graph (Fig. 1 steps 5-7).

Composes the L1 Pallas kernels — per-layer factorization then the
activation-liveness scan — and aggregates per Eq. 1 plus the overhead
terms the Rust coordinator supplies per request:

    M_peak = (persistent + bucket + max(transient, step_t)) * (1 + frac)
             + cuda_ctx

where persistent = sum(M_param) + sum(M_grad) + sum(M_opt) and transient
is the liveness peak over the forward/backward timeline.

This module is build-time only: `aot.py` lowers `predict_peak` once per
(B, L) capacity variant to HLO text; the Rust runtime executes it via
PJRT. It is never imported at request time.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import factor_kernel, peak_scan
from .kernels import schema as S


@functools.partial(jax.jit, static_argnames=("interpret",))
def predict_peak(features, overheads, *, interpret=True):
    """Batched peak-memory prediction.

    features:  [B, L, F] f32 layer-feature rows (execution order, padded
               with VALID=0 rows up to the capacity L).
    overheads: [B, NUM_OVERHEADS] f32 per-request overhead terms.
    returns:   [B, NUM_OUTPUTS] f32 (MiB) — see schema.OUT_*.
    """
    factors = factor_kernel.factor_predict(features, interpret=interpret)
    scan = peak_scan.peak_scan(factors, interpret=interpret)

    param_tot = jnp.sum(factors[..., S.F_PARAM], axis=-1)
    grad_tot = jnp.sum(factors[..., S.F_GRAD], axis=-1)
    opt_tot = jnp.sum(factors[..., S.F_OPT], axis=-1)
    act_tot = scan[..., peak_scan.SCAN_ACT_TOTAL]
    transient = scan[..., peak_scan.SCAN_TRANSIENT]
    fwd_peak = scan[..., peak_scan.SCAN_FWD_PEAK]

    persistent = param_tot + grad_tot + opt_tot
    bucket = overheads[..., S.OH_GRAD_BUCKET_MIB]
    step_t = overheads[..., S.OH_STEP_TRANSIENT_MIB]
    dynamic = jnp.maximum(transient, step_t)
    raw = persistent + bucket + dynamic
    peak = raw * (1.0 + overheads[..., S.OH_ALLOC_FRAC]) + overheads[
        ..., S.OH_CUDA_CTX_MIB
    ]

    return jnp.stack(
        [peak, param_tot, grad_tot, opt_tot, act_tot, transient, persistent, fwd_peak],
        axis=-1,
    )
