"""L1 Pallas kernel: the paper's *factor predictor* (Fig. 1 step 6).

Maps a `[B, L, F]` layer-feature matrix to `[B, L, 8]` per-layer factor
MiB — the four paper factors (M_param, M_grad, M_opt, M_act) plus the
transient columns the liveness scan consumes.

The kernel is purely elementwise over layer rows, tiled `[1, BL, F]` so a
block is BL*F*4 B of VMEM (8 KiB at BL=128, F=20) — trivially resident.
On a real TPU this is VPU work (no MXU); we lower with interpret=True for
CPU-PJRT execution (Mosaic custom-calls cannot run on the CPU plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import schema as S

DEFAULT_BLOCK_L = 128


def _factor_block(f_ref, o_ref):
    """Per-block factor math. f_ref: [1, BL, F] -> o_ref: [1, BL, 8]."""
    f = f_ref[0]  # [BL, F]
    inv_mib = 1.0 / S.MIB

    param_elems = f[:, S.PARAM_ELEMS]
    valid = f[:, S.VALID]
    trainable = f[:, S.TRAINABLE]

    # M_param: resident weights (sharded only under ZeRO-3).
    m_param = param_elems * f[:, S.PARAM_BYTES] * f[:, S.PARAM_SHARD]
    # M_grad: gradients exist only for trainable layers; ZeRO>=2 shards them.
    m_grad = param_elems * f[:, S.GRAD_BYTES] * trainable * f[:, S.GRAD_SHARD]
    # M_opt: optimizer states + fp32 master copy; ZeRO>=1 shards them.
    m_opt = (
        param_elems
        * (f[:, S.OPT_STATE_MULT] * f[:, S.OPT_BYTES] + f[:, S.MASTER_BYTES])
        * trainable
        * f[:, S.OPT_SHARD]
    )
    # M_act: retained only when backward traverses the layer; checkpointing
    # keeps a fraction.
    m_act = (
        f[:, S.ACT_ELEMS]
        * f[:, S.ACT_BYTES]
        * f[:, S.ON_BWD_PATH]
        * f[:, S.RECOMPUTE_KEEP]
    )
    m_eph = f[:, S.EPHEMERAL_ELEMS] * f[:, S.ACT_BYTES]
    m_bwd = f[:, S.BWD_TRANSIENT_ELEMS] * f[:, S.ACT_BYTES]

    out = jnp.stack(
        [
            m_param * inv_mib * valid,
            m_grad * inv_mib * valid,
            m_opt * inv_mib * valid,
            m_act * inv_mib * valid,
            m_eph * inv_mib * valid,
            f[:, S.WORKSPACE_MIB] * valid,  # already MiB
            m_bwd * inv_mib * valid,
            valid,
        ],
        axis=-1,
    )
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def factor_predict(features, *, block_l=DEFAULT_BLOCK_L, interpret=True):
    """Per-layer factorization. features: [B, L, F] f32 -> [B, L, 8] f32."""
    b, l, f = features.shape
    assert f == S.NUM_FEATURES, f"feature dim {f} != {S.NUM_FEATURES}"
    block_l = min(block_l, l)
    assert l % block_l == 0, f"L={l} not divisible by block_l={block_l}"
    grid = (b, l // block_l)
    return pl.pallas_call(
        _factor_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_l, S.NUM_FEATURES), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_l, S.NUM_FACTOR_COLS), lambda i, j: (i, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, l, S.NUM_FACTOR_COLS), jnp.float32),
        interpret=interpret,
    )(features)
