"""L1 Pallas kernel: activation-liveness timeline scan.

Given per-layer factor rows in *execution order* (the parser emits layers
in forward order), computes the transient memory peaks of one training
step:

  fwd_live(i) = cumsum_{j<=i} act_j          (activations accumulate)
  fwd_peak    = max_i fwd_live(i) + eph_i + ws_i
  bwd_peak    = max_i fwd_live(i) + bwd_i + ws_i
      (backward releases act_i only *after* computing grads that need
       ws_i + bwd_i on top of everything up to and including layer i)

One grid step per batch row; the whole `[1, L, 8]` factor block lives in
VMEM (L=4096 rows -> 128 KiB, far under the ~16 MiB VMEM budget — see
DESIGN.md Hardware-Adaptation). The cumulative scan is the TPU-idiomatic
replacement for the single-threadblock prefix scan a CUDA port would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import schema as S

# scan output columns ([B, 4])
SCAN_ACT_TOTAL = 0
SCAN_FWD_PEAK = 1
SCAN_BWD_PEAK = 2
SCAN_TRANSIENT = 3  # max(fwd, bwd)
NUM_SCAN_COLS = 4


def _scan_block(f_ref, o_ref):
    f = f_ref[0]  # [L, 8]
    act = f[:, S.F_ACT]
    eph = f[:, S.F_EPHEMERAL]
    ws = f[:, S.F_WORKSPACE]
    bwd = f[:, S.F_BWD_TRANSIENT]

    live = jnp.cumsum(act)
    fwd_peak = jnp.max(live + eph + ws)
    bwd_peak = jnp.max(live + bwd + ws)
    o_ref[0] = jnp.stack(
        [live[-1], fwd_peak, bwd_peak, jnp.maximum(fwd_peak, bwd_peak)]
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def peak_scan(factors, *, interpret=True):
    """Liveness scan. factors: [B, L, 8] f32 -> [B, 4] f32 (MiB)."""
    b, l, c = factors.shape
    assert c == S.NUM_FACTOR_COLS
    return pl.pallas_call(
        _scan_block,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, l, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, NUM_SCAN_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, NUM_SCAN_COLS), jnp.float32),
        interpret=interpret,
    )(factors)
