"""Pure-jnp correctness oracles for the L1 kernels and the L2 model.

These are the ground truth the pytest/hypothesis suite checks the Pallas
kernels against, and the reference the Rust analytical predictor mirrors
(rust/src/predictor/analytical.rs implements the identical equations in
f32 so the tensorized and analytical paths agree bit-for-bit-ish).
"""

import jax.numpy as jnp

from . import schema as S


def factor_predict_ref(features):
    """[B, L, F] -> [B, L, 8] per-layer factor MiB (see factor_kernel)."""
    f = features
    inv_mib = 1.0 / S.MIB
    pe = f[..., S.PARAM_ELEMS]
    valid = f[..., S.VALID]
    tr = f[..., S.TRAINABLE]

    m_param = pe * f[..., S.PARAM_BYTES] * f[..., S.PARAM_SHARD]
    m_grad = pe * f[..., S.GRAD_BYTES] * tr * f[..., S.GRAD_SHARD]
    m_opt = (
        pe
        * (f[..., S.OPT_STATE_MULT] * f[..., S.OPT_BYTES] + f[..., S.MASTER_BYTES])
        * tr
        * f[..., S.OPT_SHARD]
    )
    m_act = (
        f[..., S.ACT_ELEMS]
        * f[..., S.ACT_BYTES]
        * f[..., S.ON_BWD_PATH]
        * f[..., S.RECOMPUTE_KEEP]
    )
    m_eph = f[..., S.EPHEMERAL_ELEMS] * f[..., S.ACT_BYTES]
    m_bwd = f[..., S.BWD_TRANSIENT_ELEMS] * f[..., S.ACT_BYTES]

    return jnp.stack(
        [
            m_param * inv_mib * valid,
            m_grad * inv_mib * valid,
            m_opt * inv_mib * valid,
            m_act * inv_mib * valid,
            m_eph * inv_mib * valid,
            f[..., S.WORKSPACE_MIB] * valid,
            m_bwd * inv_mib * valid,
            valid,
        ],
        axis=-1,
    )


def peak_scan_ref(factors):
    """[B, L, 8] -> [B, 4] (act_total, fwd_peak, bwd_peak, transient)."""
    act = factors[..., S.F_ACT]
    eph = factors[..., S.F_EPHEMERAL]
    ws = factors[..., S.F_WORKSPACE]
    bwd = factors[..., S.F_BWD_TRANSIENT]

    live = jnp.cumsum(act, axis=-1)
    fwd_peak = jnp.max(live + eph + ws, axis=-1)
    bwd_peak = jnp.max(live + bwd + ws, axis=-1)
    return jnp.stack(
        [live[..., -1], fwd_peak, bwd_peak, jnp.maximum(fwd_peak, bwd_peak)],
        axis=-1,
    )


def predict_peak_ref(features, overheads):
    """Full L2 reference: Eq. 1 + liveness + overheads.

    features: [B, L, F], overheads: [B, NUM_OVERHEADS] -> [B, NUM_OUTPUTS].
    """
    factors = factor_predict_ref(features)
    scan = peak_scan_ref(factors)

    param_tot = jnp.sum(factors[..., S.F_PARAM], axis=-1)
    grad_tot = jnp.sum(factors[..., S.F_GRAD], axis=-1)
    opt_tot = jnp.sum(factors[..., S.F_OPT], axis=-1)
    act_tot = scan[..., 0]
    transient = scan[..., 3]
    fwd_peak = scan[..., 1]

    persistent = param_tot + grad_tot + opt_tot
    bucket = overheads[..., S.OH_GRAD_BUCKET_MIB]
    step_t = overheads[..., S.OH_STEP_TRANSIENT_MIB]
    dynamic = jnp.maximum(transient, step_t)
    raw = persistent + bucket + dynamic
    peak = raw * (1.0 + overheads[..., S.OH_ALLOC_FRAC]) + overheads[
        ..., S.OH_CUDA_CTX_MIB
    ]

    return jnp.stack(
        [peak, param_tot, grad_tot, opt_tot, act_tot, transient, persistent, fwd_peak],
        axis=-1,
    )
