"""Feature schema shared by L1 kernels, L2 model, ref oracle and the Rust
feature encoder (rust/src/parser/features.rs mirrors these indices).

Each layer of the parsed multimodal model is one row of F f32 features.
All byte quantities are converted to MiB inside the kernels (values stay
well under 2^20, so f32 absolute error is < 1 KiB at 80 GiB scale).

Keep in sync with DESIGN.md `Feature schema` and features.rs.
"""

# ---- feature column indices (input [B, L, F]) ------------------------------
PARAM_ELEMS = 0  # parameter elements in this layer
PARAM_BYTES = 1  # bytes per element of resident params (2 = bf16/fp16)
TRAINABLE = 2  # 1.0 if params receive optimizer updates
ON_BWD_PATH = 3  # 1.0 if backward traverses the layer (acts retained)
GRAD_BYTES = 4  # bytes per element of gradients (0 when frozen)
OPT_STATE_MULT = 5  # optimizer state elems per param elem (Adam = 2)
OPT_BYTES = 6  # bytes per element of optimizer state (4 = fp32)
MASTER_BYTES = 7  # bytes per element of fp32 master copy (mixed precision)
ACT_ELEMS = 8  # retained activation elements (already x MBS, seq)
ACT_BYTES = 9  # bytes per element of activations
EPHEMERAL_ELEMS = 10  # transient forward workspace elems (freed within op)
GRAD_SHARD = 11  # gradient shard factor (1/DP under ZeRO>=2, else 1)
OPT_SHARD = 12  # optimizer shard factor (1/DP under ZeRO>=1, else 1)
PARAM_SHARD = 13  # parameter shard factor (1/DP under ZeRO-3, else 1)
RECOMPUTE_KEEP = 14  # fraction of activations kept under ckpt (1 = all)
WORKSPACE_MIB = 15  # fixed per-op workspace, already in MiB
BWD_TRANSIENT_ELEMS = 16  # transient backward buffer elements
RESERVED_17 = 17
VALID = 18  # 1.0 = real row, 0.0 = padding
RESERVED_19 = 19

NUM_FEATURES = 20

# ---- per-layer factor output columns ([B, L, NUM_FACTOR_COLS]) -------------
F_PARAM = 0  # M_param (MiB)
F_GRAD = 1  # M_grad (MiB)
F_OPT = 2  # M_opt (MiB, includes fp32 master copy)
F_ACT = 3  # M_act retained (MiB)
F_EPHEMERAL = 4  # transient fwd (MiB)
F_WORKSPACE = 5  # fixed workspace (MiB)
F_BWD_TRANSIENT = 6  # transient bwd (MiB)
F_VALID = 7

NUM_FACTOR_COLS = 8

# ---- overhead vector columns (input [B, NUM_OVERHEADS]) --------------------
OH_CUDA_CTX_MIB = 0  # CUDA context + cuBLAS/NCCL handles
OH_ALLOC_FRAC = 1  # caching-allocator rounding/fragmentation fraction
OH_GRAD_BUCKET_MIB = 2  # ZeRO-2 reduce-bucket flat buffers
OH_STEP_TRANSIENT_MIB = 3  # optimizer-step temporaries
OH_RESERVED_4 = 4
OH_RESERVED_5 = 5
OH_RESERVED_6 = 6
OH_RESERVED_7 = 7

NUM_OVERHEADS = 8

# ---- prediction output columns ([B, NUM_OUTPUTS]) --------------------------
OUT_PEAK = 0  # predicted peak (MiB) -- Eq. 1 + overheads
OUT_PARAM = 1  # sum M_param
OUT_GRAD = 2  # sum M_grad
OUT_OPT = 3  # sum M_opt
OUT_ACT = 4  # sum retained M_act
OUT_TRANSIENT = 5  # max(fwd_peak, bwd_peak) liveness transient
OUT_PERSISTENT = 6  # param+grad+opt persistent base
OUT_FWD_PEAK = 7  # forward liveness peak

NUM_OUTPUTS = 8

MIB = float(1024 * 1024)

SCHEMA_VERSION = 1
