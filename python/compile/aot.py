"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The
text parser on the Rust side (HloModuleProto::from_text_file) reassigns
ids and round-trips cleanly — see /opt/xla-example/README.md.

Emits one artifact per (B, L) capacity variant plus a manifest.json the
Rust runtime uses to pick the smallest variant that fits a request batch.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import schema as S

# (batch capacity, layer capacity) variants. LLaVA-1.5-7B parses to ~700
# fine-grained layers; 13B to ~900. L=1024 covers both; L=2048 is headroom
# for larger zoo entries. B=1 serves interactive requests, B=8 the batcher.
VARIANTS = [(1, 1024), (8, 1024), (4, 2048)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(b: int, l: int) -> str:
    feat = jax.ShapeDtypeStruct((b, l, S.NUM_FEATURES), jnp.float32)
    over = jax.ShapeDtypeStruct((b, S.NUM_OVERHEADS), jnp.float32)
    lowered = jax.jit(model.predict_peak).lower(feat, over)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias")
    args = ap.parse_args()

    out_dir = (
        os.path.dirname(os.path.abspath(args.out)) if args.out else args.out_dir
    )
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "schema_version": S.SCHEMA_VERSION,
        "num_features": S.NUM_FEATURES,
        "num_overheads": S.NUM_OVERHEADS,
        "num_outputs": S.NUM_OUTPUTS,
        "variants": [],
    }
    for b, l in VARIANTS:
        name = f"predictor_b{b}_l{l}.hlo.txt"
        path = os.path.join(out_dir, name)
        text = lower_variant(b, l)
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append(
            {"file": name, "batch": b, "layers": l, "bytes": len(text)}
        )
        print(f"wrote {name}: {len(text)} chars")

    # Legacy alias expected by the Makefile dependency graph.
    if args.out:
        with open(args.out, "w") as f:
            f.write(lower_variant(*VARIANTS[0]))
        print(f"wrote {args.out}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(VARIANTS)} variants)")


if __name__ == "__main__":
    main()
