"""L2 end-to-end predict_peak vs oracle + Eq.-1 semantics + monotonicity."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels import schema as S
from tests.gen import random_features, random_overheads

RNG = np.random.default_rng(2)


def test_matches_ref_basic():
    f = random_features(RNG, 4, 256)
    o = random_overheads(RNG, 4)
    got = np.asarray(model.predict_peak(f, o))
    want = np.asarray(ref.predict_peak_ref(f, o))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    l=st.sampled_from([128, 512, 1024]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_hypothesis(b, l, seed):
    rng = np.random.default_rng(seed)
    f = random_features(rng, b, l)
    o = random_overheads(rng, b)
    got = np.asarray(model.predict_peak(f, o))
    want = np.asarray(ref.predict_peak_ref(f, o))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_eq1_decomposition():
    """persistent == param + grad + opt; peak >= persistent."""
    f = random_features(RNG, 2, 256)
    o = random_overheads(RNG, 2)
    out = np.asarray(model.predict_peak(f, o))
    np.testing.assert_allclose(
        out[:, S.OUT_PERSISTENT],
        out[:, S.OUT_PARAM] + out[:, S.OUT_GRAD] + out[:, S.OUT_OPT],
        rtol=1e-5,
    )
    assert np.all(out[:, S.OUT_PEAK] >= out[:, S.OUT_PERSISTENT])


def test_peak_monotone_in_activations():
    """Scaling activation elements up never decreases the predicted peak."""
    f = random_features(RNG, 1, 256)
    o = random_overheads(RNG, 1)
    base = np.asarray(model.predict_peak(f, o))[0, S.OUT_PEAK]
    f2 = f.copy()
    f2[..., S.ACT_ELEMS] *= 2.0
    bigger = np.asarray(model.predict_peak(f2, o))[0, S.OUT_PEAK]
    assert bigger >= base - 1e-3


def test_peak_monotone_in_dp_sharding():
    """More DP sharding (smaller shard factors) never increases the peak."""
    f = random_features(RNG, 1, 256)
    f[..., S.GRAD_SHARD] = 1.0
    f[..., S.OPT_SHARD] = 1.0
    o = random_overheads(RNG, 1)
    base = np.asarray(model.predict_peak(f, o))[0, S.OUT_PEAK]
    f8 = f.copy()
    f8[..., S.GRAD_SHARD] = 1.0 / 8.0
    f8[..., S.OPT_SHARD] = 1.0 / 8.0
    sharded = np.asarray(model.predict_peak(f8, o))[0, S.OUT_PEAK]
    assert sharded <= base + 1e-3


def test_overheads_additive_ctx():
    f = random_features(RNG, 1, 128)
    o = random_overheads(RNG, 1)
    o[:, S.OH_ALLOC_FRAC] = 0.0
    p0 = np.asarray(model.predict_peak(f, o))[0, S.OUT_PEAK]
    o2 = o.copy()
    o2[:, S.OH_CUDA_CTX_MIB] += 100.0
    p1 = np.asarray(model.predict_peak(f, o2))[0, S.OUT_PEAK]
    assert abs((p1 - p0) - 100.0) < 1e-2


def test_batch_rows_independent():
    """Row i of a batched call equals a single-row call."""
    f = random_features(RNG, 4, 256)
    o = random_overheads(RNG, 4)
    full = np.asarray(model.predict_peak(f, o))
    for i in range(4):
        single = np.asarray(model.predict_peak(f[i : i + 1], o[i : i + 1]))
        np.testing.assert_allclose(full[i], single[0], rtol=1e-6, atol=1e-4)
