"""Shared generators for the python test-suite: plausible layer-feature
rows (and hypothesis strategies over them) matching the schema the Rust
feature encoder emits."""

import numpy as np

from compile.kernels import schema as S


def random_features(rng, b, l, valid_frac=0.8):
    """Random but schema-plausible feature batch [b, l, F] f32."""
    f = np.zeros((b, l, S.NUM_FEATURES), dtype=np.float32)
    n_valid = max(1, int(l * valid_frac))
    f[:, :n_valid, S.VALID] = 1.0
    f[..., S.PARAM_ELEMS] = rng.uniform(0, 2e8, (b, l))
    f[..., S.PARAM_BYTES] = rng.choice([2.0, 4.0], (b, l))
    f[..., S.TRAINABLE] = rng.choice([0.0, 1.0], (b, l))
    f[..., S.ON_BWD_PATH] = np.maximum(
        f[..., S.TRAINABLE], rng.choice([0.0, 1.0], (b, l))
    )
    f[..., S.GRAD_BYTES] = f[..., S.TRAINABLE] * rng.choice([2.0, 4.0], (b, l))
    f[..., S.OPT_STATE_MULT] = rng.choice([0.0, 1.0, 2.0], (b, l))
    f[..., S.OPT_BYTES] = 4.0
    f[..., S.MASTER_BYTES] = rng.choice([0.0, 4.0], (b, l))
    f[..., S.ACT_ELEMS] = rng.uniform(0, 5e7, (b, l))
    f[..., S.ACT_BYTES] = rng.choice([2.0, 4.0], (b, l))
    f[..., S.EPHEMERAL_ELEMS] = rng.uniform(0, 1e7, (b, l))
    dp = rng.choice([1.0, 2.0, 4.0, 8.0])
    f[..., S.GRAD_SHARD] = 1.0 / dp
    f[..., S.OPT_SHARD] = 1.0 / dp
    f[..., S.PARAM_SHARD] = 1.0
    f[..., S.RECOMPUTE_KEEP] = rng.choice([0.1, 0.5, 1.0], (b, l))
    f[..., S.WORKSPACE_MIB] = rng.uniform(0, 64.0, (b, l))
    f[..., S.BWD_TRANSIENT_ELEMS] = rng.uniform(0, 1e7, (b, l))
    return f


def random_overheads(rng, b):
    o = np.zeros((b, S.NUM_OVERHEADS), dtype=np.float32)
    o[:, S.OH_CUDA_CTX_MIB] = rng.uniform(300, 900, b)
    o[:, S.OH_ALLOC_FRAC] = rng.uniform(0.0, 0.1, b)
    o[:, S.OH_GRAD_BUCKET_MIB] = rng.uniform(0, 2000, b)
    o[:, S.OH_STEP_TRANSIENT_MIB] = rng.uniform(0, 4000, b)
    return o
