"""AOT lowering sanity: every variant lowers to parseable HLO text and
the manifest matches the schema the Rust runtime checks against."""

import json
import os
import tempfile

from compile import aot
from compile.kernels import schema as S


def test_variants_cover_llava():
    # LLaVA-1.5-7B parses to ~827 fine-grained layers, 13B to ~947.
    assert any(l >= 1024 for _, l in aot.VARIANTS)
    assert any(b >= 8 for b, _ in aot.VARIANTS)


def test_lower_variant_produces_hlo_text():
    text = aot.lower_variant(1, 64)
    assert "HloModule" in text
    assert "ENTRY" in text
    # two parameters: features [1,64,F] and overheads [1,OH]
    assert f"64,{S.NUM_FEATURES}" in text.replace(" ", "")


def test_manifest_written(tmp_path=None):
    out = tempfile.mkdtemp()
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", out]
    try:
        # monkeypatch variants to tiny shapes for speed
        orig = aot.VARIANTS
        aot.VARIANTS = [(1, 32), (2, 32)]
        aot.main()
        aot.VARIANTS = orig
    finally:
        sys.argv = argv
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["schema_version"] == S.SCHEMA_VERSION
    assert manifest["num_features"] == S.NUM_FEATURES
    assert manifest["num_outputs"] == S.NUM_OUTPUTS
    assert len(manifest["variants"]) == 2
    for v in manifest["variants"]:
        assert os.path.exists(os.path.join(out, v["file"]))
