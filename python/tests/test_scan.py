"""Pallas liveness-scan kernel vs pure-jnp oracle + analytic properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import factor_kernel, peak_scan, ref
from tests.gen import random_features

RNG = np.random.default_rng(1)


def _factors(rng, b, l, valid_frac=0.8):
    f = random_features(rng, b, l, valid_frac)
    return np.asarray(ref.factor_predict_ref(f))


def test_matches_ref_basic():
    fac = _factors(RNG, 3, 256)
    got = np.asarray(peak_scan.peak_scan(fac))
    want = np.asarray(ref.peak_scan_ref(fac))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    l=st.sampled_from([64, 128, 512, 1024]),
    valid_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_hypothesis(b, l, valid_frac, seed):
    fac = _factors(np.random.default_rng(seed), b, l, valid_frac)
    got = np.asarray(peak_scan.peak_scan(fac))
    want = np.asarray(ref.peak_scan_ref(fac))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_peaks_bound_act_total():
    """fwd/bwd peaks are >= the steady retained-activation total when
    ephemeral/workspace columns are nonnegative."""
    fac = _factors(RNG, 4, 512)
    out = np.asarray(peak_scan.peak_scan(fac))
    assert np.all(out[:, peak_scan.SCAN_FWD_PEAK] >= out[:, peak_scan.SCAN_ACT_TOTAL] - 1e-4)
    assert np.all(
        out[:, peak_scan.SCAN_TRANSIENT]
        >= np.maximum(out[:, peak_scan.SCAN_FWD_PEAK], out[:, peak_scan.SCAN_BWD_PEAK]) - 1e-4
    )


def test_transient_is_max_of_fwd_bwd():
    fac = _factors(RNG, 2, 256)
    out = np.asarray(peak_scan.peak_scan(fac))
    np.testing.assert_allclose(
        out[:, peak_scan.SCAN_TRANSIENT],
        np.maximum(out[:, peak_scan.SCAN_FWD_PEAK], out[:, peak_scan.SCAN_BWD_PEAK]),
        rtol=1e-7,
    )


def test_all_zero_rows():
    fac = np.zeros((2, 128, 8), dtype=np.float32)
    out = np.asarray(peak_scan.peak_scan(fac))
    assert np.all(out == 0.0)


def test_single_spike_layer():
    """One layer with a huge ephemeral buffer dominates the fwd peak."""
    fac = np.zeros((1, 64, 8), dtype=np.float32)
    fac[0, :, 3] = 1.0  # 1 MiB retained act per layer (F_ACT col = 3)
    fac[0, 10, 4] = 500.0  # F_EPHEMERAL
    out = np.asarray(peak_scan.peak_scan(fac))[0]
    # live at layer 10 = 11 MiB; + 500 ephemeral
    assert abs(out[peak_scan.SCAN_FWD_PEAK] - 511.0) < 1e-3
    assert abs(out[peak_scan.SCAN_ACT_TOTAL] - 64.0) < 1e-3
