"""Pallas factor kernel vs pure-jnp oracle — the CORE correctness signal.

Covers: exact agreement with ref on random schema-plausible inputs,
hypothesis sweeps over shapes/valid fractions/block sizes, padding-row
semantics, factor masking (frozen layers), ZeRO shard scaling, and
hand-computed golden values.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import factor_kernel, ref
from compile.kernels import schema as S
from tests.gen import random_features

RNG = np.random.default_rng(0)


def test_matches_ref_basic():
    f = random_features(RNG, 2, 256)
    got = np.asarray(factor_kernel.factor_predict(f))
    want = np.asarray(ref.factor_predict_ref(f))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    l_blocks=st.integers(min_value=1, max_value=8),
    block_l=st.sampled_from([32, 64, 128]),
    valid_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_hypothesis(b, l_blocks, block_l, valid_frac, seed):
    rng = np.random.default_rng(seed)
    l = l_blocks * block_l
    f = random_features(rng, b, l, valid_frac)
    got = np.asarray(factor_kernel.factor_predict(f, block_l=block_l))
    want = np.asarray(ref.factor_predict_ref(f))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_padding_rows_are_zero():
    f = random_features(RNG, 1, 128, valid_frac=0.5)
    got = np.asarray(factor_kernel.factor_predict(f))
    invalid = f[0, :, S.VALID] == 0.0
    assert np.all(got[0, invalid] == 0.0)


def test_frozen_layer_has_no_grad_or_opt():
    f = random_features(RNG, 1, 128)
    f[..., S.TRAINABLE] = 0.0
    got = np.asarray(factor_kernel.factor_predict(f))
    assert np.all(got[..., S.F_GRAD] == 0.0)
    assert np.all(got[..., S.F_OPT] == 0.0)
    # params still resident
    assert got[..., S.F_PARAM].sum() > 0.0


def test_off_backward_path_has_no_activations():
    f = random_features(RNG, 1, 128)
    f[..., S.ON_BWD_PATH] = 0.0
    f[..., S.TRAINABLE] = 0.0
    got = np.asarray(factor_kernel.factor_predict(f))
    assert np.all(got[..., S.F_ACT] == 0.0)


def test_zero2_shards_grad_and_opt_not_param():
    f = random_features(RNG, 1, 128)
    f[..., S.TRAINABLE] = 1.0
    f[..., S.GRAD_SHARD] = 1.0
    f[..., S.OPT_SHARD] = 1.0
    f[..., S.PARAM_SHARD] = 1.0
    base = np.asarray(factor_kernel.factor_predict(f))
    f8 = f.copy()
    f8[..., S.GRAD_SHARD] = 1.0 / 8.0
    f8[..., S.OPT_SHARD] = 1.0 / 8.0
    sharded = np.asarray(factor_kernel.factor_predict(f8))
    np.testing.assert_allclose(
        sharded[..., S.F_GRAD], base[..., S.F_GRAD] / 8.0, rtol=1e-6
    )
    np.testing.assert_allclose(
        sharded[..., S.F_OPT], base[..., S.F_OPT] / 8.0, rtol=1e-6
    )
    np.testing.assert_allclose(sharded[..., S.F_PARAM], base[..., S.F_PARAM])


def test_golden_single_layer():
    """Hand-computed: 1M-param bf16 linear, Adam fp32 + master, 2M acts."""
    f = np.zeros((1, 32, S.NUM_FEATURES), dtype=np.float32)
    f[0, 0, S.PARAM_ELEMS] = 1e6
    f[0, 0, S.PARAM_BYTES] = 2.0
    f[0, 0, S.TRAINABLE] = 1.0
    f[0, 0, S.ON_BWD_PATH] = 1.0
    f[0, 0, S.GRAD_BYTES] = 2.0
    f[0, 0, S.OPT_STATE_MULT] = 2.0
    f[0, 0, S.OPT_BYTES] = 4.0
    f[0, 0, S.MASTER_BYTES] = 4.0
    f[0, 0, S.ACT_ELEMS] = 2e6
    f[0, 0, S.ACT_BYTES] = 2.0
    f[0, 0, S.GRAD_SHARD] = 1.0
    f[0, 0, S.OPT_SHARD] = 1.0
    f[0, 0, S.PARAM_SHARD] = 1.0
    f[0, 0, S.RECOMPUTE_KEEP] = 1.0
    f[0, 0, S.VALID] = 1.0
    got = np.asarray(factor_kernel.factor_predict(f))[0, 0]
    mib = 1024.0 * 1024.0
    assert got[S.F_PARAM] == pytest.approx(2e6 / mib, rel=1e-6)
    assert got[S.F_GRAD] == pytest.approx(2e6 / mib, rel=1e-6)
    assert got[S.F_OPT] == pytest.approx(12e6 / mib, rel=1e-6)  # 2*4 + 4 per elem
    assert got[S.F_ACT] == pytest.approx(4e6 / mib, rel=1e-6)


def test_block_size_invariance():
    f = random_features(RNG, 2, 256)
    a = np.asarray(factor_kernel.factor_predict(f, block_l=32))
    b = np.asarray(factor_kernel.factor_predict(f, block_l=256))
    np.testing.assert_allclose(a, b, rtol=1e-7)


def test_rejects_bad_feature_dim():
    bad = np.zeros((1, 32, S.NUM_FEATURES + 1), dtype=np.float32)
    with pytest.raises(AssertionError):
        factor_kernel.factor_predict(bad)
